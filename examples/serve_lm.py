"""Serving example: batched requests through the Engine + KV-cache PQ.

    PYTHONPATH=src python examples/serve_lm.py

1. Serves a smoke LM with continuous batching (more requests than slots).
2. Builds a k-means++ product-quantization codebook over the KV cache of a
   long prompt (paper integration #1) and reports compression/error.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.registry import get_model
from repro.serve import Engine, ServeConfig, kvquant


def main():
    cfg = get_config("deepseek-7b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    # --- batched generation ------------------------------------------------
    eng = Engine(cfg, params, ServeConfig(max_batch=4, max_len=96,
                                          max_new_tokens=16))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(8, 48))
               .astype(np.int32) for _ in range(10)]
    t0 = time.perf_counter()
    outs = eng.generate(prompts)
    dt = time.perf_counter() - t0
    n_tok = sum(map(len, outs))
    print(f"[serve_lm] {len(prompts)} requests -> {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile)")

    # --- KV-cache PQ (long-context path) ------------------------------------
    long_prompt = rng.integers(0, cfg.vocab, size=512).astype(np.int32)
    _, cache = model.prefill(params, {"tokens": jnp.asarray(long_prompt)[None]})
    k_cache = cache["k"]                       # (L, 1, S, KH, hd)
    flat = k_cache.reshape(-1, k_cache.shape[-1])
    pq = kvquant.compress_kv(jax.random.PRNGKey(1), flat, n_sub=4)
    err = float(kvquant.reconstruction_error(flat, pq))
    ratio = kvquant.compression_ratio(flat, pq)
    print(f"[serve_lm] KV PQ: {ratio:.1f}x compression, "
          f"relative reconstruction MSE {err:.4f}")
    print("[serve_lm] OK")


if __name__ == "__main__":
    main()
