"""Data-pipeline example: semantic dedup via distributed-style k-means++
(paper integration #3).

    PYTHONPATH=src python examples/semdedup_pipeline.py

Builds a corpus of document embeddings with planted near-duplicates, runs
SemDeDup (cluster with k-means++ seeding, drop near-duplicates within
clusters), and verifies the planted duplicates are removed while distinct
documents survive.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.semdedup import semdedup
from repro.data.synthetic import blobs


def main():
    rng = np.random.default_rng(0)
    base, _ = blobs(2000, 64, 20, seed=0, spread=0.2)
    # plant 300 near-duplicates (tiny perturbations of existing docs)
    dup_src = rng.integers(0, 2000, size=300)
    dups = base[dup_src] + rng.normal(0, 1e-3, size=(300, 64)).astype(np.float32)
    corpus = jnp.asarray(np.concatenate([base, dups]))

    res = semdedup(jax.random.PRNGKey(0), corpus, k=20, threshold=0.999)
    kept = int(res.n_kept)
    dup_kept = int(res.keep_mask[2000:].sum())
    print(f"[semdedup] corpus 2300 docs (300 planted dups) -> kept {kept}")
    print(f"[semdedup] planted duplicates surviving: {dup_kept} / 300")
    assert dup_kept < 30, "dedup failed to catch planted duplicates"
    assert int(res.keep_mask[:2000].sum()) > 1900, "too many originals dropped"
    print("[semdedup] OK")


if __name__ == "__main__":
    main()
