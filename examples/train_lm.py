"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a reduced gemma2-family config (~100M params), the synthetic token
stream, AdamW with warmup+cosine, periodic async checkpoints, preemption
handling, and the straggler monitor — the production loop end to end.
Loss must fall from ~uniform (log V ~ 6.2) to well below it.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.common import ArchConfig
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import TokenStream
from repro.launch.step import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, train

# ~100M params: 8 layers x d512 (vocab 8192 dominates: 2*8192*512 = 8.4M,
# per-layer ~ 3.4M; total ~ 96M fp32)
CFG = ArchConfig(
    name="train-demo-100m", family="dense",
    n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2304,
    vocab=8192, remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
        jax.eval_shape(lambda: init_train_state(
            CFG, jax.random.PRNGKey(0))["params"])))
    print(f"[train_lm] {CFG.name}: {n_params/1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    opt = AdamWConfig(lr=6e-4, warmup_steps=args.steps // 20 + 1,
                      decay_steps=args.steps)
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(CFG, opt), donate_argnums=(0,))
    stream = TokenStream(CFG.vocab, seed=0)
    pipe = DataPipeline(lambda s: stream.read(s, args.batch, args.seq),
                        prefetch=2)
    ckpt = CheckpointManager(args.ckpt, keep=2)
    state, summary = train(state, step_fn, pipe,
                           LoopConfig(total_steps=args.steps, save_every=100,
                                      log_every=20),
                           ckpt=ckpt)
    losses = summary["losses"]
    k = max(len(losses) // 10, 1)
    print(f"[train_lm] loss: first-{k} mean {np.mean(losses[:k]):.3f} -> "
          f"last-{k} mean {np.mean(losses[-k:]):.3f} "
          f"(uniform would be {np.log(CFG.vocab):.3f})")
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not fall!"
    print("[train_lm] OK")


if __name__ == "__main__":
    main()
