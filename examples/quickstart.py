"""Quickstart: the paper's algorithm end-to-end in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Generates the paper's workload (2-D Gaussian blobs).
2. Seeds with serial k-means++ (the CPU baseline) and the parallel variant —
   identical seeds under a matched PRNG key (the paper's quality claim).
3. Runs Lloyd clustering and reports inertia + timing for each variant.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import kmeans, kmeanspp, quality
from repro.data.synthetic import blobs

N, D, K = 100_000, 2, 50     # paper sweeps N=1-10M, k=10-100 (GPU-sized)


def main():
    print(f"k-means++ quickstart: N={N}, d={D}, k={K}")
    pts = jnp.asarray(blobs(N, D, K, seed=0)[0])
    key = jax.random.PRNGKey(0)

    results = {}
    for variant in ("serial", "global", "fused"):
        t0 = time.perf_counter()
        res = kmeanspp(key, pts, K, variant=variant, sampler="cdf")
        jax.block_until_ready(res.centroids)
        t_compile = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = kmeanspp(key, pts, K, variant=variant, sampler="cdf")
        jax.block_until_ready(res.centroids)
        t = time.perf_counter() - t0
        phi = float(quality.inertia(pts, res.centroids))
        results[variant] = res
        print(f"  seeding [{variant:7s}]  {t*1e3:8.1f} ms  "
              f"(first call incl. compile {t_compile*1e3:7.0f} ms)  "
              f"phi={phi:.1f}")

    same = (results["serial"].indices == results["fused"].indices).all()
    print(f"  serial == parallel seeds: {bool(same)}  (paper's quality claim)")

    t0 = time.perf_counter()
    out = kmeans(key, pts, K, variant="fused", max_iters=50)
    jax.block_until_ready(out.centroids)
    print(f"  + Lloyd clustering: {time.perf_counter()-t0:.2f}s, "
          f"{int(out.n_iters)} iters, final phi={float(out.inertia):.1f}")


if __name__ == "__main__":
    main()
