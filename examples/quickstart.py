"""Quickstart: the paper's algorithm end-to-end in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Generates the paper's workload (2-D Gaussian blobs).
2. Seeds through the ClusterEngine with the serial reference backend (the
   paper's CPU baseline) and the parallel backends — identical seeds under a
   matched PRNG key (the paper's quality claim).
3. Runs Lloyd clustering, a streaming mini-batch fit, and a batched
   multi-problem fit, reporting inertia + timing for each.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import quality
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs

N, D, K = 100_000, 2, 50     # paper sweeps N=1-10M, k=10-100 (GPU-sized)


def main():
    print(f"k-means++ quickstart: N={N}, d={D}, k={K}")
    np_pts = blobs(N, D, K, seed=0)[0]
    pts = jnp.asarray(np_pts)
    key = jax.random.PRNGKey(0)

    results = {}
    for backend in ("serial", "global", "fused"):
        eng = ClusterEngine(backend)
        t0 = time.perf_counter()
        res = eng.seed(key, pts, K)
        jax.block_until_ready(res.centroids)
        t_compile = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = eng.seed(key, pts, K)
        jax.block_until_ready(res.centroids)
        t = time.perf_counter() - t0
        phi = float(quality.inertia(pts, res.centroids))
        results[backend] = res
        print(f"  seeding [{backend:7s}]  {t*1e3:8.1f} ms  "
              f"(first call incl. compile {t_compile*1e3:7.0f} ms)  "
              f"phi={phi:.1f}")

    same = (results["serial"].indices == results["fused"].indices).all()
    print(f"  serial == parallel seeds: {bool(same)}  (paper's quality claim)")

    eng = ClusterEngine("fused")
    t0 = time.perf_counter()
    out = eng.kmeans(key, pts, K, max_iters=50)
    jax.block_until_ready(out.centroids)
    print(f"  + Lloyd clustering: {time.perf_counter()-t0:.2f}s, "
          f"{int(out.n_iters)} iters, final phi={float(out.inertia):.1f}")

    # streaming mini-batch: the device only ever holds one 4096-point batch
    batch = 4096

    def read_fn(step):
        lo = (step * batch) % N
        return np_pts[lo:lo + batch]

    t0 = time.perf_counter()
    mb = eng.fit_minibatch(results["fused"].centroids, read_fn, n_batches=24)
    jax.block_until_ready(mb.centroids)
    phi_mb = float(quality.inertia(pts, mb.centroids))
    print(f"  + mini-batch Lloyd: {time.perf_counter()-t0:.2f}s over "
          f"{int(mb.n_iters)} x {batch}-point batches, phi={phi_mb:.1f}")

    # batched multi-problem: 4 tenants clustered in one compiled call
    B, n_small = 4, 8192
    bpts = jnp.stack([jnp.asarray(blobs(n_small, D, 8, seed=s)[0])
                      for s in range(B)])
    t0 = time.perf_counter()
    bout = eng.kmeans_batched(jax.random.PRNGKey(1), bpts, 8, max_iters=20)
    jax.block_until_ready(bout.centroids)
    print(f"  + batched multi-problem: {B} problems of n={n_small} in "
          f"{time.perf_counter()-t0:.2f}s, phi={[round(float(p), 2) for p in bout.inertia]}")


if __name__ == "__main__":
    main()
