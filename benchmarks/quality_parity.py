"""Paper §I claim — "reduce running time while MAINTAINING THE QUALITY of the
serial algorithm". Inertia parity across variants + init-method comparison
(random vs k-means++ vs k-means||), plus the beyond-paper integrations'
quality numbers (KV-PQ reconstruction, kmeans++ router balance)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, emit
from repro.core import kmeans_parallel_init, quality, random_init  # noqa: F401
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs

N, D, K = (2 ** 12, 2, 16) if SMOKE else (2 ** 15, 2, 50)
REPEATS = 1 if SMOKE else 3

ENGINE = ClusterEngine("fused")
SERIAL = ClusterEngine("serial")
BF16 = ClusterEngine("fused", precision="bf16")


def run(rows: list):
    pts = jnp.asarray(blobs(N, D, K, seed=0)[0])
    seeds = {}
    for s in range(REPEATS):
        key = jax.random.PRNGKey(s)
        seeds[("serial", s)] = SERIAL.seed(key, pts, K).centroids
        seeds[("fused", s)] = ENGINE.seed(key, pts, K).centroids
        seeds[("gumbel", s)] = ENGINE.seed(key, pts, K,
                                           sampler="gumbel").centroids
        seeds[("tiled", s)] = ENGINE.seed(key, pts, K,
                                          sampler="tiled").centroids
        seeds[("bf16", s)] = BF16.seed(key, pts, K).centroids
        seeds[("kmeans||", s)] = kmeans_parallel_init(key, pts, K).centroids
        seeds[("random", s)] = random_init(key, pts, K).centroids

    # bf16 rows: seeding AND Lloyd stream bf16 — the paper-config inertia
    # must land within rtol of the fp32 rows (the quality-safety claim for
    # bf16 streaming; the tier-1 test pins the same bound)
    for method in ("serial", "fused", "gumbel", "tiled", "bf16", "kmeans||",
                   "random"):
        eng = BF16 if method == "bf16" else ENGINE
        phi_seed, phi_final = [], []
        for s in range(REPEATS):
            c = seeds[(method, s)]
            phi_seed.append(float(quality.inertia(pts, c)))
            phi_final.append(float(
                eng.fit(pts, c, max_iters=30).inertia))
        rows.append({"bench": "quality_parity", "method": method,
                     "phi_seed": f"{sum(phi_seed)/REPEATS:.1f}",
                     "phi_after_lloyd": f"{sum(phi_final)/REPEATS:.1f}"})


def run_minibatch(rows: list):
    """Streaming mini-batch rows: bf16 streaming now covers the mini-batch
    path too — its inertia drift vs the fp32 stream is the pinned quality
    claim (the tier-1 test bounds the same drift at 15%)."""
    import numpy as np
    pts = jnp.asarray(blobs(N, D, K, seed=1)[0])
    np_pts = np.asarray(pts)
    batch = 512

    def read_fn(step):
        lo = (step * batch) % N
        return np_pts[lo:lo + batch]

    seeds = ENGINE.seed(jax.random.PRNGKey(3), pts[:batch], K).centroids
    n_batches = 16 if SMOKE else 64
    for method, eng in (("minibatch-fp32", ENGINE), ("minibatch-bf16", BF16)):
        mb = eng.fit_minibatch(seeds, read_fn, n_batches=n_batches)
        rows.append({"bench": "quality_parity", "method": method,
                     "phi_seed": f"{float(quality.inertia(pts, seeds)):.1f}",
                     "phi_after_lloyd":
                         f"{float(quality.inertia(pts, mb.centroids)):.1f}"})


def run_integrations(rows: list):
    if SMOKE:  # the PQ/router integrations are minutes-scale; skip in smoke
        return
    # KV-PQ reconstruction error (paper integration #1)
    from repro.serve import kvquant
    key = jax.random.PRNGKey(0)
    base = jax.random.normal(key, (16, 128))
    coef = jax.random.normal(jax.random.fold_in(key, 1), (8192, 16))
    kv = coef @ base + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 2), (8192, 128))
    for n_sub in (4, 8, 16):
        pq = kvquant.compress_kv(key, kv, n_sub=n_sub)
        rows.append({"bench": "kvpq", "method": f"n_sub={n_sub}",
                     "phi_seed": f"{float(kvquant.reconstruction_error(kv, pq)):.4f}",
                     "phi_after_lloyd": f"{kvquant.compression_ratio(kv, pq):.1f}x"})

    # router init balance (paper integration #2)
    from repro.core.quality import balance
    emb = jnp.asarray(blobs(4096, 64, 16, seed=1, spread=0.3)[0])
    rand_router = jax.random.normal(key, (64, 16)) * 0.02
    km = ENGINE.seed(jax.random.PRNGKey(2), emb, 16).centroids
    km_router = (km / (jnp.linalg.norm(km, axis=1, keepdims=True) + 1e-6)).T
    for name, router in (("random", rand_router), ("kmeans++", km_router)):
        a = jnp.argmax(emb @ router, axis=-1)
        rows.append({"bench": "router_init_balance", "method": name,
                     "phi_seed": f"{float(balance(a, 16)):.2f}",
                     "phi_after_lloyd": ""})


def main():
    rows = []
    run(rows)
    run_minibatch(rows)
    run_integrations(rows)
    emit(rows, ["bench", "method", "phi_seed", "phi_after_lloyd"])


if __name__ == "__main__":
    main()
