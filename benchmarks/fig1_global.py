"""Paper Fig. 1 — serial (CPU loop) vs parallel-global seeding.

Two sweeps, exactly as in the paper:
  (a) k fixed at 50, N sweeps (paper: 1M..10M on GPU; host-scaled here),
  (b) N fixed, k sweeps 10..100.

'serial' is the paper's CPU baseline (ClusterEngine reference backend in
serial mode: fori_loop, one point at a time); 'global' is the parallel update
materialized to memory with a separate reduction pass (reference backend in
global mode). d=2 as in the paper. Speedup shape — growing with N and with
k — is the reproduction target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, sweep, time_fn
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs

# host-scaled N sweep (the paper's 1M..10M needs a GPU-sized host; the
# SHAPE of the curve is the claim, and N=2^17 on one CPU core already shows it)
N_SWEEP = [2 ** 13, 2 ** 14, 2 ** 15, 2 ** 16, 2 ** 17]
K_SWEEP = [10, 25, 50, 75, 100]
N_FIX = 2 ** 15
K_FIX = 50

SERIAL = ClusterEngine("serial")
GLOBAL = ClusterEngine("global")


def run(rows: list):
    from benchmarks.common import SMOKE
    key = jax.random.PRNGKey(0)
    k_fix = 10 if SMOKE else K_FIX  # smoke shrinks k as well as the sweeps
    for n in sweep(N_SWEEP):
        pts = jnp.asarray(blobs(n, 2, k_fix, seed=0)[0])
        t_ser = time_fn(lambda: SERIAL.seed(key, pts, k_fix),
                        warmup=1, iters=3)
        t_par = time_fn(lambda: GLOBAL.seed(key, pts, k_fix),
                        warmup=1, iters=3)
        rows.append({"bench": "fig1a_points_sweep", "n": n, "k": k_fix,
                     "serial_s": f"{t_ser:.4f}", "parallel_s": f"{t_par:.4f}",
                     "speedup": f"{t_ser / t_par:.2f}"})
    for k in sweep(K_SWEEP):
        pts = jnp.asarray(blobs(N_FIX, 2, k, seed=0)[0])
        t_ser = time_fn(lambda: SERIAL.seed(key, pts, k),
                        warmup=1, iters=3)
        t_par = time_fn(lambda: GLOBAL.seed(key, pts, k),
                        warmup=1, iters=3)
        rows.append({"bench": "fig1b_clusters_sweep", "n": N_FIX, "k": k,
                     "serial_s": f"{t_ser:.4f}", "parallel_s": f"{t_par:.4f}",
                     "speedup": f"{t_ser / t_par:.2f}"})


def main():
    rows = []
    run(rows)
    emit(rows, ["bench", "n", "k", "serial_s", "parallel_s", "speedup"])


if __name__ == "__main__":
    main()
