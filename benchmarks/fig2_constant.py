"""Paper Fig. 2 — constant-memory variant: centroids resident on-chip.

TPU analogue (DESIGN.md §2): the Pallas kernel's centroid block pinned in
VMEM across grid steps (`resident=True`) vs re-fetched per step
(`resident=False`, the global-memory behaviour). The paper reports 2-11%
gains growing with k (Fig. 2c); we measure the same comparison structurally —
on this CPU host the kernels run in interpret mode, so we *additionally*
report the XLA-fused variant timing ratio (fused vs global), which captures
the same data-movement saving at the HLO level. Both sides go through the
ClusterEngine backends ('global' reference vs 'fused').
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, sweep, time_fn
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs

K_SWEEP = [10, 30, 50, 100]
N = 2 ** 15

GLOBAL = ClusterEngine("global")
FUSED = ClusterEngine("fused")


def run(rows: list):
    key = jax.random.PRNGKey(0)
    for k in sweep(K_SWEEP):
        pts = jnp.asarray(blobs(N, 2, k, seed=0)[0])
        t_glob = time_fn(lambda: GLOBAL.seed(key, pts, k), warmup=1, iters=3)
        t_res = time_fn(lambda: FUSED.seed(key, pts, k), warmup=1, iters=3)
        gain = 100.0 * (t_glob - t_res) / t_glob
        rows.append({"bench": "fig2_constant_vs_global", "n": N, "k": k,
                     "global_s": f"{t_glob:.4f}", "resident_s": f"{t_res:.4f}",
                     "gain_pct": f"{gain:.1f}"})

    # kernel-level VMEM residency: count HBM<->VMEM traffic structurally
    # (bytes the BlockSpec pipeline must move per seeding round)
    for k in sweep((8, 64, 512)):
        d = 64
        n = 2 ** 14
        block_n = 1024
        grid = n // block_n
        stream = n * d * 4 + n * 4 * 2            # points + min_d2 in/out
        resident_bytes = stream + k * d * 4       # centroids fetched ONCE
        global_bytes = stream + grid * k * d * 4  # re-fetched per grid step
        rows.append({"bench": "fig2_vmem_traffic_model", "n": n, "k": k,
                     "global_s": global_bytes, "resident_s": resident_bytes,
                     "gain_pct": f"{100 * (global_bytes - resident_bytes) / global_bytes:.1f}"})


def main():
    rows = []
    run(rows)
    emit(rows, ["bench", "n", "k", "global_s", "resident_s", "gain_pct"])


if __name__ == "__main__":
    main()
