"""Round-kernel traffic trajectory — what the bound-gated, mixed-precision
round kernels actually save (ISSUE 3 tentpole; ISSUE 4 adds the ``fit``
section for the bounded Lloyd assignment round; ISSUE 5 adds the per-POINT
prune rate and the hierarchical-accumulator HBM columns).

Columns per seeding run:

  skip_rate     — fraction of point tiles the triangle-inequality bound
                  skipped, per round (exact: fp32 results are bitwise
                  identical to the ungated kernels). Reported vs round
                  number: early rounds touch everything, later rounds prune.
  prune_rate    — fraction of ALL points whose k-way distance update the
                  per-point (fine-level) bound short-circuited inside
                  ACTIVE tiles — the modelled FLOP saving the tile gate
                  alone cannot reach (also exact / bitwise-pinned).
  bytes/round   — modelled HBM traffic of one round at the engine's tile
                  height: active tiles stream (points + cached norms +
                  min_d2 in/out + partial/tile-max scalars); skipped tiles
                  stream NOTHING. bf16 streams the point tile at half width
                  (norms/min_d2 stay fp32).
  seconds       — wall time of the full seed call, fp32 vs bf16 (the bf16
                  win is a bandwidth effect, so expect parity on this CPU
                  host and ~2x on the round-kernel fraction on TPU).
  time_ms       — median-of-5 wall clock in ms (2 warmup runs discarded)
                  of the same call, sitting next to the modelled bytes so
                  measured and modelled costs share a row (ISSUE 8); NaN
                  on pallas rows off-TPU, where interpret mode would time
                  the interpreter rather than the kernel.

The ``fit_traffic`` / ``fit_skip_vs_iter`` rows track the ASSIGNMENT round
(the Lloyd hot path): per-iteration skip/prune rates of the two-level
movement-bound gate on label-sorted vs shuffled vs Morton-ordered rows, the
modelled bytes per iteration of the gated assignment kernel, and the
accumulator-HBM columns ``accum_hbm`` (hierarchical tile → super-tile
layout, O(n_super·k·d)) vs ``accum_hbm_flat`` (what the flat per-tile
layout of PR 4 would cost, O(n_tiles·k·d)) — the closed "memory trade".

The ``guard_overhead`` section (ISSUE 7) prices the entry guards: the
``validate="sanitize"`` policy costs ONE streaming ``isfinite`` reduction
over the points per entry call (``n*d*4`` modelled bytes, the
``guard_hbm`` column) and nothing per round — ``guard_overhead`` is that
one-shot cost as a fraction of the modelled traffic of the guarded call
itself (``call_hbm``: the end-to-end ``kmeans()`` entry — shared prologue
+ k gated seeding rounds + the Lloyd iterations; acceptance: < 5% on the
smoke shape), with wall-clock rows for validate on vs off pinning that
clean input pays ~nothing.

Data is label-sorted blobs: tile-level pruning needs spatially coherent
tiles (Capó et al.) — the unsorted control row shows skip_rate ~= 0, and
the `morton` row shows how much `repro.data.ordering` recovers without
labels (its per-point prune_rate stays > 0 even where tile skips sag).

Emits BENCH_round.json via REPRO_BENCH_OUT; benchmarks/BENCH_round.json is
the checked-in smoke-mode baseline tracking the trajectory across PRs. The
CI smoke run schema-checks the fit sections for the prune_rate/accum_hbm
columns (benchmarks/check_schema.py)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, emit, time_fn, time_ms, write_json
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs
from repro.kernels.ops import choose_block_n
from repro.tune import measure as tune_measure


def _interpreted(backend: str) -> bool:
    """Pallas rows run in interpret mode off-TPU — their wall clock times
    the interpreter, so the time_ms column reports NaN there."""
    return backend == "pallas" and jax.default_backend() != "tpu"

N, D, K = (2 ** 14, 2, 4) if SMOKE else (2 ** 17, 8, 16)
SEEDS = 8 if SMOKE else 32
# pallas kernels interpret on CPU — keep their probe small off-TPU
N_PALLAS = N if jax.default_backend() == "tpu" else min(N, 2 ** 14)


def coherent_blobs(n: int, seed: int = 0) -> jax.Array:
    pts, labels = blobs(n, D, K, seed=seed)
    return jnp.asarray(pts[np.argsort(labels, kind="stable")])


def round_bytes(n: int, skip_rate: float, dtype_bytes: int) -> int:
    """Modelled HBM bytes of ONE gated round at the engine tile height.
    The formula lives in ``repro.tune.measure`` (the autotuner scores
    candidates with the same model, so the benchmark column and the tuner
    objective can't drift); this wrapper just pins the module's shape."""
    bn = choose_block_n(n, D, 1, batched=True)
    return tune_measure.model_seed_round_bytes(
        n, D, block_n=bn, skip_rate=skip_rate, dtype_bytes=dtype_bytes)


def run(rows: list):
    key = jax.random.PRNGKey(0)
    for backend, n in (("fused", N), ("pallas", N_PALLAS)):
        for layout, pts in (("coherent", coherent_blobs(n)),
                            ("shuffled", jnp.asarray(blobs(n, D, K,
                                                           seed=0)[0]))):
            n_tiles = -(-n // ClusterEngine(backend).backend.seed_tile(n, D))
            for precision in ("fp32", "bf16"):
                peng = ClusterEngine(backend, precision=precision)
                # measure skips from THIS precision's own run: the bf16 gate
                # carries bf16-derived tile_max, so its trajectory can differ
                res = peng.seed(key, pts, SEEDS)
                skips = np.asarray(res.skipped, np.float64) / n_tiles
                prunes = np.asarray(res.pruned, np.float64) / n
                t = time_fn(lambda: jax.block_until_ready(
                    peng.seed(key, pts, SEEDS)), iters=3)
                tms = time_ms(lambda: jax.block_until_ready(
                    peng.seed(key, pts, SEEDS)),
                    interpreted=_interpreted(backend))
                rows.append({
                    "bench": "round_traffic", "backend": backend,
                    "layout": layout, "precision": precision, "n": n,
                    "rounds": SEEDS,
                    "skip_rate_mean": round(float(skips.mean()), 4),
                    "skip_rate_last": round(float(skips[-4:].mean()), 4),
                    "prune_rate": round(float(prunes.mean()), 4),
                    "bytes_per_round": round_bytes(
                        n, float(skips.mean()),
                        2 if precision == "bf16" else 4),
                    "time_ms": round(tms, 3),
                    "seconds": round(t, 6),
                })


def run_skip_vs_round(rows: list):
    """The per-round trajectory on coherent data (the acceptance column)."""
    eng = ClusterEngine("fused")
    pts = coherent_blobs(N)
    res = eng.seed(jax.random.PRNGKey(1), pts, SEEDS)
    n_tiles = -(-N // eng.backend.seed_tile(N, D))
    for r, (s, p) in enumerate(zip(np.asarray(res.skipped),
                                   np.asarray(res.pruned))):
        rows.append({
            "bench": "skip_vs_round", "backend": "fused",
            "layout": "coherent", "precision": "fp32", "n": N, "rounds": r,
            "skip_rate_mean": round(float(s) / n_tiles, 4),
            "skip_rate_last": "",
            "prune_rate": round(float(p) / N, 4),
            "bytes_per_round": round_bytes(N, float(s) / n_tiles, 4),
            "time_ms": "",
            "seconds": "",
        })


def run_guard_overhead(rows: list):
    """Entry-guard cost (ISSUE 7): ``validate='sanitize'`` streams the
    points through one ``isfinite`` reduction at ENTRY — ``n*d*4`` modelled
    bytes, once per call (``guard_hbm``) — and nothing per round. The
    honest amortization unit is the end-to-end ``kmeans()`` call (one
    guarded entry, one shared prologue, k seeding rounds + the Lloyd
    iterations): ``call_hbm`` is that call's modelled traffic with guards
    off, and ``guard_overhead = guard_hbm / call_hbm`` (acceptance: < 5%
    on the smoke shape). The timing rows pin that clean input pays ~nothing
    in wall clock too (the guard returns clean arrays unchanged, bitwise)."""
    key = jax.random.PRNGKey(4)
    pts = coherent_blobs(N)
    iters = FIT_ITERS
    base = ClusterEngine("fused", validate="off")
    # model the gated traffic from the guards-off run's own skip telemetry
    sres = base.seed(key, pts, K)
    n_tiles_seed = -(-N // base.backend.seed_tile(N, D))
    seed_skip = float(np.asarray(sres.skipped,
                                 np.float64).mean()) / n_tiles_seed
    fres = base.fit(pts, sres.centroids, max_iters=iters, tol=-1.0)
    n_tiles_fit = -(-N // base.backend.seed_tile(N, D, K))
    fit_skip = float(np.asarray(fres.skipped,
                                np.float64).mean()) / n_tiles_fit
    call_hbm = (N * (D + 1) * 4                      # prologue: points+norms
                + K * round_bytes(N, seed_skip, 4)   # k seeding rounds
                + iters * fit_bytes(N, fit_skip, 4, d=D, k=K))
    guard_hbm = N * D * 4          # one isfinite stream over the points
    for policy in ("off", "sanitize"):
        eng = ClusterEngine("fused", validate=policy)
        t = time_fn(lambda: jax.block_until_ready(
            eng.kmeans(key, pts, K, max_iters=iters,
                       tol=-1.0).centroids), iters=3)
        tms = time_ms(lambda: jax.block_until_ready(
            eng.kmeans(key, pts, K, max_iters=iters, tol=-1.0).centroids))
        cost = guard_hbm if policy != "off" else 0
        rows.append({
            "bench": "guard_overhead", "backend": "fused",
            "layout": "coherent", "precision": "fp32", "n": N,
            "rounds": K + iters, "validate": policy,
            "guard_hbm": cost,
            "call_hbm": call_hbm,
            "guard_overhead": round(cost / call_hbm, 4),
            "time_ms": round(tms, 3),
            "seconds": round(t, 6),
        })


# the fit section uses well-separated high-d blobs (the regime where the
# movement bound pays) at enough tiles that blob interiors get their own
# tiles; the seeding section above keeps the paper's d=2
D_FIT, K_FIT = 8, 16
N_FIT = 2 ** 16 if SMOKE else 2 ** 17
N_FIT_PALLAS = N_FIT if jax.default_backend() == "tpu" else min(N_FIT, 2 ** 14)
FIT_ITERS = 6 if SMOKE else 10


def fit_bytes(n: int, skip_rate: float, dtype_bytes: int, *,
              d: int = None, k: int = None) -> int:
    """Modelled HBM bytes of ONE gated assignment iteration at the engine
    tile height: per ACTIVE tile the kernel streams the point block (stream
    dtype) + the fp32 cached-norms block + the int32 label / fp32 min_d2 /
    fp32 point_lb carries in, writes those three back out along with the
    partial/gap/pruned scalars, and amortizes the per-SUPER cluster
    sums/counts block over its tps tiles. The never-read aliased carries
    live in ANY memory space — no per-step DMA — and skipped tiles move
    nothing."""
    d = D_FIT if d is None else d
    k = K_FIT if k is None else k
    bn = choose_block_n(n, d, k, batched=True)
    return tune_measure.model_fit_round_bytes(
        n, d, k, block_n=bn, skip_rate=skip_rate, dtype_bytes=dtype_bytes)


def accum_hbm(n: int) -> tuple[int, int]:
    """Modelled accumulator footprint of one assignment iteration:
    (hierarchical O(n_super·k·d), flat O(n_tiles·k·d)) fp32 bytes — the
    "memory trade" closed by the tile -> super-tile -> global reduce."""
    from repro.core import bounds as bnd
    bn = choose_block_n(n, D_FIT, K_FIT, batched=True)
    n_tiles = -(-n // bn)
    n_super = -(-n_tiles // bnd.tiles_per_super(n_tiles))
    per_slot = 4 * (K_FIT * D_FIT + K_FIT)
    return n_super * per_slot, n_tiles * per_slot


def _fit_layouts(n: int):
    pts, labels = blobs(n, D_FIT, K_FIT, seed=0)
    coherent = jnp.asarray(pts[np.argsort(labels, kind="stable")])
    shuffled = jnp.asarray(pts)
    return (("coherent", coherent, None), ("shuffled", shuffled, None),
            ("morton", shuffled, "morton"))


def run_fit(rows: list):
    """Assignment-round trajectory: the movement-bound gate's skip rate and
    modelled bytes/iteration, ordered vs shuffled vs Morton-ordered."""
    key = jax.random.PRNGKey(2)
    for backend, n in (("fused", N_FIT), ("pallas", N_FIT_PALLAS)):
        eng = ClusterEngine(backend)
        n_tiles = -(-n // eng.backend.seed_tile(n, D_FIT, K_FIT))
        hier, flat = accum_hbm(n)
        for layout, pts, order in _fit_layouts(n):
            seeds = eng.seed(key, pts, K_FIT).centroids
            res = eng.fit(pts, seeds, max_iters=FIT_ITERS, tol=-1.0,
                          order=order)
            skips = np.asarray(res.skipped, np.float64) / n_tiles
            prunes = np.asarray(res.pruned, np.float64) / n
            t = time_fn(lambda: jax.block_until_ready(
                eng.fit(pts, seeds, max_iters=FIT_ITERS, tol=-1.0,
                        order=order).centroids), iters=3)
            tms = time_ms(lambda: jax.block_until_ready(
                eng.fit(pts, seeds, max_iters=FIT_ITERS, tol=-1.0,
                        order=order).centroids),
                interpreted=_interpreted(backend))
            rows.append({
                "bench": "fit_traffic", "backend": backend,
                "layout": layout, "precision": "fp32", "n": n,
                "rounds": FIT_ITERS,
                "skip_rate_mean": round(float(skips.mean()), 4),
                "skip_rate_last": round(float(skips[-3:].mean()), 4),
                "prune_rate": round(float(prunes.mean()), 4),
                "bytes_per_round": fit_bytes(n, float(skips.mean()), 4),
                "accum_hbm": hier,
                "accum_hbm_flat": flat,
                "time_ms": round(tms, 3),
                "seconds": round(t, 6),
            })


def run_fit_skip_vs_iter(rows: list):
    """The per-iteration trajectory on label-sorted blobs (the acceptance
    column: >= 50% of assignment tiles skipped by iteration 3)."""
    eng = ClusterEngine("fused")
    layout, pts, _ = _fit_layouts(N_FIT)[0]
    seeds = eng.seed(jax.random.PRNGKey(3), pts, K_FIT).centroids
    res = eng.fit(pts, seeds, max_iters=FIT_ITERS, tol=-1.0)
    n_tiles = -(-N_FIT // eng.backend.seed_tile(N_FIT, D_FIT, K_FIT))
    hier, flat = accum_hbm(N_FIT)
    for it, (s, p) in enumerate(zip(np.asarray(res.skipped),
                                    np.asarray(res.pruned))):
        rows.append({
            "bench": "fit_skip_vs_iter", "backend": "fused",
            "layout": layout, "precision": "fp32", "n": N_FIT, "rounds": it,
            "skip_rate_mean": round(float(s) / n_tiles, 4),
            "skip_rate_last": "",
            "prune_rate": round(float(p) / N_FIT, 4),
            "bytes_per_round": fit_bytes(N_FIT, float(s) / n_tiles, 4),
            "accum_hbm": hier,
            "accum_hbm_flat": flat,
            "time_ms": "",
            "seconds": "",
        })


def main():
    rows: list = []
    run(rows)
    run_skip_vs_round(rows)
    run_guard_overhead(rows)
    run_fit(rows)
    run_fit_skip_vs_iter(rows)
    header = ["bench", "backend", "layout", "precision", "n", "rounds",
              "skip_rate_mean", "skip_rate_last", "prune_rate",
              "bytes_per_round", "accum_hbm", "accum_hbm_flat",
              "validate", "guard_hbm", "call_hbm", "guard_overhead",
              "time_ms", "seconds"]
    emit(rows, header)
    write_json("round", {
        "meta": {"smoke": SMOKE, "N": N, "D": D, "K": K, "seeds": SEEDS,
                 "n_fit": N_FIT, "d_fit": D_FIT, "k_fit": K_FIT,
                 "fit_iters": FIT_ITERS,
                 "jax_backend": jax.default_backend()},
        "rows": rows,
    })


if __name__ == "__main__":
    main()
