"""Beyond-paper: the two new engine scenarios.

(a) streaming mini-batch Lloyd vs full-batch Lloyd — same seeds, same data;
    reports wall time and the inertia gap (massive-data k-means in the spirit
    of Capó et al. 2018: the device only ever holds one batch).
(b) batched multi-problem clustering — B independent (n, d) problems in ONE
    compiled vmap call vs a python loop of single-problem calls (the
    serve/semdedup many-tenant scenario).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SMOKE, emit, time_fn
from repro.core import quality
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs

N, D, K = (2 ** 13, 2, 8) if SMOKE else (2 ** 17, 2, 32)
BATCH = 1024
N_BATCHES = 8 if SMOKE else 64
B_PROBLEMS = 2 if SMOKE else 8
N_PER_PROBLEM = 1024 if SMOKE else 4096


def run_minibatch(rows: list):
    eng = ClusterEngine("fused")
    np_pts = blobs(N, D, K, seed=0)[0]
    full = jnp.asarray(np_pts)
    key = jax.random.PRNGKey(0)
    seeds = eng.seed(key, full[:4 * BATCH], K).centroids

    def read_fn(step):
        lo = (step * BATCH) % N
        return np_pts[lo:lo + BATCH]

    t0 = time.perf_counter()
    full_res = eng.fit(full, seeds, max_iters=30)
    jax.block_until_ready(full_res.centroids)
    t_full = time.perf_counter() - t0

    t0 = time.perf_counter()
    mb_res = eng.fit_minibatch(seeds, read_fn, n_batches=N_BATCHES)
    jax.block_until_ready(mb_res.centroids)
    t_mb = time.perf_counter() - t0

    phi_full = float(full_res.inertia)
    phi_mb = float(quality.inertia(full, mb_res.centroids))
    rows.append({"bench": "minibatch_vs_full", "config": f"n={N},k={K}",
                 "baseline_s": f"{t_full:.3f}", "engine_s": f"{t_mb:.3f}",
                 "quality": f"phi_ratio={phi_mb / phi_full:.3f}"})


def run_batched(rows: list):
    eng = ClusterEngine("fused")
    bpts = jnp.stack([jnp.asarray(blobs(N_PER_PROBLEM, D, 8, seed=s)[0])
                      for s in range(B_PROBLEMS)])
    key = jax.random.PRNGKey(1)

    t_batched = time_fn(
        lambda: eng.kmeans_batched(key, bpts, 8, max_iters=15).centroids,
        warmup=1, iters=3)

    keys = jax.random.split(key, B_PROBLEMS)

    def looped():
        outs = []
        for b in range(B_PROBLEMS):
            outs.append(eng.kmeans(keys[b], bpts[b], 8,
                                   max_iters=15).centroids)
        return outs

    t_loop = time_fn(looped, warmup=1, iters=3)
    rows.append({"bench": "batched_multi_problem",
                 "config": f"B={B_PROBLEMS},n={N_PER_PROBLEM}",
                 "baseline_s": f"{t_loop:.3f}", "engine_s": f"{t_batched:.3f}",
                 "quality": f"speedup={t_loop / t_batched:.2f}x"})


def main():
    rows = []
    run_minibatch(rows)
    run_batched(rows)
    emit(rows, ["bench", "config", "baseline_s", "engine_s", "quality"])


if __name__ == "__main__":
    main()
