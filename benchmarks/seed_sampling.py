"""Seeding-round sampler trajectory — the two-level tile sampler vs the full
inverse-CDF re-scan, plus the batched multi-problem kernel path.

Every seeding round already pays the round kernel (min-update + per-tile
partials). What this module measures is the traffic AFTER the kernel:

  cdf    — O(n) cumsum + searchsorted over the full min_d2 array per round
  gumbel — O(n) log + noise + argmax per round
  tiled  — inverse-CDF over the ~n/block_n tile partials, then a scan of
           only the chosen tile: O(n/bn + bn) reads per round

plus `kmeans_batched` fused-vs-pallas, where the pallas path runs the
batch-grid kernels (one launch covers every tenant problem).

Emits BENCH_seed.json via REPRO_BENCH_OUT; benchmarks/BENCH_seed.json is the
checked-in smoke-mode baseline tracking the trajectory across PRs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, emit, time_fn, write_json
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs
from repro.kernels.ops import choose_block_n

N, D, K = (2 ** 12, 2, 8) if SMOKE else (2 ** 16, 16, 32)
# pallas kernels interpret on CPU — keep their probe small off-TPU
N_PALLAS = N if jax.default_backend() == "tpu" else min(N, 2 ** 12)
BB, BN, BK = (4, 2 ** 10, 4) if SMOKE else (16, 2 ** 13, 16)


def _post_round_reads(n: int, sampler: str) -> int:
    bn = choose_block_n(n, D, 1, batched=True)
    if sampler == "tiled":
        return -(-n // bn) + bn
    return n


def _skip_rate(eng: ClusterEngine, res, n: int) -> float:
    """Mean fraction of tiles the bound gate skipped per round (comparable
    to the round_traffic module's skip_rate column)."""
    if res.skipped is None:
        return 0.0
    n_tiles = -(-n // eng.backend.seed_tile(n, D))
    return float(jnp.mean(res.skipped / n_tiles))


def run(rows: list):
    key = jax.random.PRNGKey(0)
    for backend, n in (("fused", N), ("pallas", N_PALLAS)):
        pts = jnp.asarray(blobs(n, D, K, seed=0)[0])
        eng = ClusterEngine(backend)
        for sampler in ("cdf", "gumbel", "tiled"):
            res = eng.seed(key, pts, K, sampler=sampler)  # warms the jit too
            t = time_fn(lambda: jax.block_until_ready(
                eng.seed(key, pts, K, sampler=sampler)))
            rows.append({
                "bench": "seed_sampler", "backend": backend,
                "sampler": sampler, "n": n, "k": K,
                "post_round_reads": _post_round_reads(n, sampler),
                "skip_rate": round(_skip_rate(eng, res, n), 4),
                "seconds": round(t, 6),
            })


def run_batched(rows: list):
    keys = jax.random.split(jax.random.PRNGKey(1), BB)
    bpts = jnp.stack([jnp.asarray(blobs(BN, D, BK, seed=s)[0])
                      for s in range(BB)])
    for backend in ("fused", "pallas"):
        eng = ClusterEngine(backend)
        seeds = eng.seed_batched(keys, bpts, BK)
        t = time_fn(lambda: jax.block_until_ready(
            eng.kmeans_batched(keys, bpts, BK, max_iters=5)), iters=3)
        rows.append({
            "bench": "kmeans_batched", "backend": backend, "sampler": "cdf",
            "n": BN, "k": BK, "post_round_reads": BB * BN,
            "skip_rate": round(_skip_rate(eng, seeds, BN), 4),
            "seconds": round(t, 6),
        })


def main():
    rows: list = []
    run(rows)
    run_batched(rows)
    header = ["bench", "backend", "sampler", "n", "k",
              "post_round_reads", "skip_rate", "seconds"]
    emit(rows, header)
    write_json("seed", {
        "meta": {"smoke": SMOKE, "N": N, "D": D, "K": K,
                 "batched": {"B": BB, "n": BN, "k": BK},
                 "jax_backend": jax.default_backend()},
        "rows": rows,
    })


if __name__ == "__main__":
    main()
