"""Seeding-round sampler trajectory — the two-level tile sampler vs the full
inverse-CDF re-scan, plus the batched multi-problem kernel path.

Every seeding round already pays the round kernel (min-update + per-tile
partials). What this module measures is the traffic AFTER the kernel:

  cdf       — O(n) cumsum + searchsorted over the full min_d2 array per round
  gumbel    — O(n) log + noise + argmax per round
  tiled     — inverse-CDF over the ~n/block_n tile partials, then a scan of
              only the chosen tile: O(n/bn + bn) reads per round
  rejection — the same tiled draw from a STALE envelope + an O(P·d)
              single-row exact check; the full refresh runs only every
              `refresh_block` seeds, so the modelled rows-touched-per-seed
              (`seed_reads`, from the skip telemetry) goes SUB-LINEAR

plus `kmeans_batched` fused-vs-pallas, where the pallas path runs the
batch-grid kernels (one launch covers every tenant problem), and a
`rejection_vs_tiled` smoke row at k=64 whose `reads_ratio` pins the
sub-linear seeding claim (ISSUE 6: >= 4x fewer modelled reads).

ISSUE 9 adds the coarse-to-fine columns and the `hier_vs_flat` section.
Every seed row now carries `envelope_ratio` (mean fraction of tiles whose
stale mass the per-tile movement cap clipped per round) and
`supers_visited` (total super-tile windows the hierarchical draw read).
`hier_vs_flat` sweeps proposal x refresh_block x layout at k=64, n=2^16 on
a tuned 512-row tile and pins the two sides of the coarse-to-fine trade:

  * on the NATURAL (shuffled) layout the tiled baseline cannot skip, so a
    bigger refresh block is pure profit: `proposal='hier'` at
    refresh_block>=16 models >=8x fewer rows-touched-per-seed than `tiled`
    (vs the >=4x PR 6 pinned at refresh_block=8 — which is the asymptotic
    ceiling there: refresh streams n/8 per seed against tiled's n);
  * on the MORTON layout tile balls are genuinely small, the movement caps
    bite (`envelope_ratio` > 0), and tightening sustains the acceptance
    rate the flat envelope loses to staleness: hier at refresh_block=8
    accepts ABOVE the PR 6 flat-envelope row (>= 0.6932), and hier at
    refresh_block=16 holds acceptance parity with flat at refresh_block=8
    while reading `hier_over_flat`x fewer rows.

Each timed row also carries a ``time_ms`` column (median-of-5 wall clock
with 2 warmup runs, NaN for pallas rows off-TPU where interpret mode would
time the interpreter) so the modelled reads and the measured cost sit side
by side (ISSUE 8).

Emits BENCH_seed.json via REPRO_BENCH_OUT; benchmarks/BENCH_seed.json is the
checked-in smoke-mode baseline tracking the trajectory across PRs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, emit, time_fn, time_ms, write_json
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs
from repro.kernels.ops import choose_block_n


def _interpreted(backend: str) -> bool:
    """Pallas rows run in interpret mode off-TPU; their time_ms is NaN."""
    return backend == "pallas" and jax.default_backend() != "tpu"

N, D, K = (2 ** 12, 2, 8) if SMOKE else (2 ** 16, 16, 32)
# pallas kernels interpret on CPU — keep their probe small off-TPU
N_PALLAS = N if jax.default_backend() == "tpu" else min(N, 2 ** 12)
BB, BN, BK = (4, 2 ** 10, 4) if SMOKE else (16, 2 ** 13, 16)


REFRESH_BLOCK = 8


def _post_round_reads(n: int, sampler: str, eng: ClusterEngine = None,
                      proposal: str = "flat") -> int:
    bn = (eng.backend.seed_tile(n, D) if eng is not None
          else choose_block_n(n, D, 1, batched=True))
    n_tiles = -(-n // bn)
    if sampler == "rejection" and proposal == "hier":
        # super -> tile -> row: one (n_super,) searchsorted, one
        # tiles_per_super window, one tile scan
        tps = (eng.backend.tiles_per_super(n_tiles) if eng is not None
               else n_tiles)
        return -(-n_tiles // tps) + tps + bn
    if sampler in ("tiled", "rejection"):
        return n_tiles + bn
    return n


def _envelope_ratio(eng: ClusterEngine, res, n: int) -> float:
    """Mean fraction of tiles the movement cap tightened per round (0.0 for
    flat proposals and non-rejection samplers)."""
    if getattr(res, "tightened", None) is None:
        return 0.0
    n_tiles = -(-n // eng.backend.seed_tile(n, D))
    return float(jnp.mean(res.tightened / n_tiles))


def _supers_visited(res) -> int:
    """Total super-tile windows the hierarchical draw read (0 for flat)."""
    if getattr(res, "supers", None) is None:
        return 0
    return int(jnp.sum(res.supers))


def _skip_rate(eng: ClusterEngine, res, n: int) -> float:
    """Mean fraction of tiles the bound gate skipped per round (comparable
    to the round_traffic module's skip_rate column)."""
    if res.skipped is None:
        return 0.0
    n_tiles = -(-n // eng.backend.seed_tile(n, D))
    return float(jnp.mean(res.skipped / n_tiles))


def _accept_rate(res) -> float:
    """Fraction of envelope proposals the exact ratio test accepted (1.0 for
    samplers whose every draw IS the final draw)."""
    if res.proposals is None:
        return 1.0
    props = float(jnp.sum(res.proposals))
    return float(jnp.sum(res.accepts)) / max(props, 1.0)


def _seed_reads(eng: ClusterEngine, res, n: int, k: int, sampler: str,
                refresh_block: int = REFRESH_BLOCK,
                proposal: str = "flat") -> float:
    """Modelled rows touched per SEED, straight from the run's telemetry:
    refresh-kernel rows streamed (tiles not skipped — untouched rejection
    rounds report skipped == all tiles, contributing zero) amortized over k,
    plus the per-round draw cost and, for rejection, the O(refresh_block)
    single-row exact checks."""
    tile = eng.backend.seed_tile(n, D)
    n_tiles = -(-n // tile)
    if res.skipped is not None:
        streamed = float(jnp.sum((n_tiles - res.skipped) * tile))
        if res.skipped.ndim == 2:  # batched: per-problem average
            streamed /= res.skipped.shape[0]
    else:
        streamed = float(n) * k
    reads = streamed / k + _post_round_reads(n, sampler, eng, proposal)
    if res.proposals is not None:
        extra = float(jnp.sum(res.proposals)) / k
        reads += extra * refresh_block  # pending-block rows per exact check
    return reads


def run(rows: list):
    key = jax.random.PRNGKey(0)
    for backend, n in (("fused", N), ("pallas", N_PALLAS)):
        pts = jnp.asarray(blobs(n, D, K, seed=0)[0])
        eng = ClusterEngine(backend)
        for sampler in ("cdf", "gumbel", "tiled", "rejection"):
            # rejection rows run the engine default proposal='hier'
            prop = "hier" if sampler == "rejection" else "-"
            res = eng.seed(key, pts, K, sampler=sampler,
                           refresh_block=REFRESH_BLOCK)  # warms the jit too
            t = time_fn(lambda: jax.block_until_ready(
                eng.seed(key, pts, K, sampler=sampler,
                         refresh_block=REFRESH_BLOCK)))
            tms = time_ms(lambda: jax.block_until_ready(
                eng.seed(key, pts, K, sampler=sampler,
                         refresh_block=REFRESH_BLOCK)),
                interpreted=_interpreted(backend))
            rows.append({
                "bench": "seed_sampler", "backend": backend,
                "sampler": sampler, "n": n, "k": K, "proposal": prop,
                "post_round_reads": _post_round_reads(n, sampler, eng,
                                                      prop),
                "skip_rate": round(_skip_rate(eng, res, n), 4),
                "accept_rate": round(_accept_rate(res), 4),
                "envelope_ratio": round(_envelope_ratio(eng, res, n), 4),
                "supers_visited": _supers_visited(res),
                "seed_reads": round(_seed_reads(
                    eng, res, n, K, sampler, proposal=prop), 1),
                "time_ms": round(tms, 3),
                "seconds": round(t, 6),
            })


def run_rejection_vs_tiled(rows: list):
    """ISSUE 6 acceptance row: modelled rows-touched-per-seed at k=64 on a
    coherent blob layout — rejection's refresh-every-8 must come in >= 4x
    under tiled's refresh-every-round. The row keeps n = 2^16 even in smoke
    mode: the seed tile caps at 4096 rows, so any smaller n is a SINGLE tile
    and the two-level draw (hence the whole sub-linearity claim) degenerates
    to a full scan — the fused engine still runs this size in milliseconds."""
    k64, n64 = 64, 2 ** 16
    key = jax.random.PRNGKey(2)
    pts = jnp.asarray(blobs(n64, D, K, seed=2)[0])
    eng = ClusterEngine("fused")
    reads = {}
    # (sampler, proposal): tiled baseline, the PR 6 flat-envelope row, and
    # the hier proposal on the identical workload (the shuffled layout keeps
    # every movement cap at +inf, so hier's cost delta here is purely the
    # coarse draw — the tightening story is the hier_vs_flat section's)
    for sampler, prop in (("tiled", "-"), ("rejection", "flat"),
                          ("rejection", "hier")):
        kw = dict(refresh_block=REFRESH_BLOCK)
        if sampler == "rejection":
            kw["proposal"] = prop
        res = eng.seed(key, pts, k64, sampler=sampler, **kw)
        t = time_fn(lambda: jax.block_until_ready(
            eng.seed(key, pts, k64, sampler=sampler, **kw)))
        tms = time_ms(lambda: jax.block_until_ready(
            eng.seed(key, pts, k64, sampler=sampler, **kw)))
        reads[(sampler, prop)] = _seed_reads(eng, res, n64, k64, sampler,
                                             proposal=prop)
        rows.append({
            "bench": "rejection_vs_tiled", "backend": "fused",
            "sampler": sampler, "n": n64, "k": k64, "proposal": prop,
            "refresh_block": 0 if sampler == "tiled" else REFRESH_BLOCK,
            "post_round_reads": _post_round_reads(n64, sampler, eng, prop),
            "skip_rate": round(_skip_rate(eng, res, n64), 4),
            "accept_rate": round(_accept_rate(res), 4),
            "envelope_ratio": round(_envelope_ratio(eng, res, n64), 4),
            "supers_visited": _supers_visited(res),
            "seed_reads": round(reads[(sampler, prop)], 1),
            "reads_ratio": 1.0 if sampler == "tiled" else
            round(reads[("tiled", "-")]
                  / max(reads[(sampler, prop)], 1.0), 2),
            "time_ms": round(tms, 3),
            "seconds": round(t, 6),
        })


def run_hier_vs_flat(rows: list):
    """ISSUE 9 acceptance rows (module docstring has the full story): the
    proposal x refresh_block x layout sweep at k=64, n=2^16 on a tuned
    512-row tile. `reads_ratio` compares against the SAME layout's tiled
    row; `hier_over_flat` against the same layout's flat refresh_block=8
    row (the PR 6 configuration)."""
    import dataclasses

    from repro.data import morton_order

    k64, n64 = 64, 2 ** 16
    key = jax.random.PRNGKey(2)
    natural = jnp.asarray(blobs(n64, D, K, seed=2)[0])
    layouts = {"natural": natural,
               "morton": jnp.take(natural, morton_order(natural)[0], axis=0)}
    grid = (("tiled", "-", 0), ("rejection", "flat", 8),
            ("rejection", "hier", 8), ("rejection", "hier", 16),
            ("rejection", "hier", 32))
    eng = ClusterEngine("fused")
    eng.backend = dataclasses.replace(eng.backend, block_n=512)
    for layout, pts in layouts.items():
        reads = {}
        for sampler, prop, rb in grid:
            kw = {} if sampler == "tiled" else {
                "refresh_block": rb, "proposal": prop}
            res = eng.seed(key, pts, k64, sampler=sampler, **kw)
            tms = time_ms(lambda: jax.block_until_ready(
                eng.seed(key, pts, k64, sampler=sampler, **kw)),
                warmup=1, iters=3)
            reads[(prop, rb)] = _seed_reads(
                eng, res, n64, k64, sampler,
                refresh_block=max(rb, 1), proposal=prop)
            rows.append({
                "bench": "hier_vs_flat", "backend": "fused",
                "sampler": sampler, "n": n64, "k": k64, "layout": layout,
                "proposal": prop, "refresh_block": rb,
                "post_round_reads": _post_round_reads(n64, sampler, eng,
                                                      prop),
                "skip_rate": round(_skip_rate(eng, res, n64), 4),
                "accept_rate": round(_accept_rate(res), 4),
                "envelope_ratio": round(_envelope_ratio(eng, res, n64), 4),
                "supers_visited": _supers_visited(res),
                "seed_reads": round(reads[(prop, rb)], 1),
                "reads_ratio": 1.0 if sampler == "tiled" else
                round(reads[("-", 0)] / max(reads[(prop, rb)], 1.0), 2),
                "hier_over_flat": float("nan") if prop != "hier" else
                round(reads[("flat", 8)] / max(reads[(prop, rb)], 1.0), 2),
                "time_ms": round(tms, 3),
                "seconds": round(tms / 1000.0, 6),
            })


def run_batched(rows: list):
    keys = jax.random.split(jax.random.PRNGKey(1), BB)
    bpts = jnp.stack([jnp.asarray(blobs(BN, D, BK, seed=s)[0])
                      for s in range(BB)])
    for backend in ("fused", "pallas"):
        eng = ClusterEngine(backend)
        seeds = eng.seed_batched(keys, bpts, BK)
        t = time_fn(lambda: jax.block_until_ready(
            eng.kmeans_batched(keys, bpts, BK, max_iters=5)), iters=3)
        tms = time_ms(lambda: jax.block_until_ready(
            eng.kmeans_batched(keys, bpts, BK, max_iters=5)),
            interpreted=_interpreted(backend))
        rows.append({
            "bench": "kmeans_batched", "backend": backend, "sampler": "cdf",
            "n": BN, "k": BK, "proposal": "-", "post_round_reads": BB * BN,
            "skip_rate": round(_skip_rate(eng, seeds, BN), 4),
            "accept_rate": 1.0,
            "envelope_ratio": 0.0, "supers_visited": 0,
            "seed_reads": round(_seed_reads(eng, seeds, BN, BK, "cdf"), 1),
            "time_ms": round(tms, 3),
            "seconds": round(t, 6),
        })


def main():
    rows: list = []
    run(rows)
    run_batched(rows)
    run_rejection_vs_tiled(rows)
    run_hier_vs_flat(rows)
    header = ["bench", "backend", "sampler", "n", "k", "layout", "proposal",
              "refresh_block", "post_round_reads", "skip_rate",
              "accept_rate", "envelope_ratio", "supers_visited",
              "seed_reads", "reads_ratio", "hier_over_flat",
              "time_ms", "seconds"]
    emit(rows, header)
    write_json("seed", {
        "meta": {"smoke": SMOKE, "N": N, "D": D, "K": K,
                 "batched": {"B": BB, "n": BN, "k": BK},
                 "jax_backend": jax.default_backend()},
        "rows": rows,
    })


if __name__ == "__main__":
    main()
