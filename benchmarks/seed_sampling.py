"""Seeding-round sampler trajectory — the two-level tile sampler vs the full
inverse-CDF re-scan, plus the batched multi-problem kernel path.

Every seeding round already pays the round kernel (min-update + per-tile
partials). What this module measures is the traffic AFTER the kernel:

  cdf       — O(n) cumsum + searchsorted over the full min_d2 array per round
  gumbel    — O(n) log + noise + argmax per round
  tiled     — inverse-CDF over the ~n/block_n tile partials, then a scan of
              only the chosen tile: O(n/bn + bn) reads per round
  rejection — the same tiled draw from a STALE envelope + an O(P·d)
              single-row exact check; the full refresh runs only every
              `refresh_block` seeds, so the modelled rows-touched-per-seed
              (`seed_reads`, from the skip telemetry) goes SUB-LINEAR

plus `kmeans_batched` fused-vs-pallas, where the pallas path runs the
batch-grid kernels (one launch covers every tenant problem), and a
`rejection_vs_tiled` smoke row at k=64 whose `reads_ratio` pins the
sub-linear seeding claim (ISSUE 6: >= 4x fewer modelled reads).

Each timed row also carries a ``time_ms`` column (median-of-5 wall clock
with 2 warmup runs, NaN for pallas rows off-TPU where interpret mode would
time the interpreter) so the modelled reads and the measured cost sit side
by side (ISSUE 8).

Emits BENCH_seed.json via REPRO_BENCH_OUT; benchmarks/BENCH_seed.json is the
checked-in smoke-mode baseline tracking the trajectory across PRs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, emit, time_fn, time_ms, write_json
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs
from repro.kernels.ops import choose_block_n


def _interpreted(backend: str) -> bool:
    """Pallas rows run in interpret mode off-TPU; their time_ms is NaN."""
    return backend == "pallas" and jax.default_backend() != "tpu"

N, D, K = (2 ** 12, 2, 8) if SMOKE else (2 ** 16, 16, 32)
# pallas kernels interpret on CPU — keep their probe small off-TPU
N_PALLAS = N if jax.default_backend() == "tpu" else min(N, 2 ** 12)
BB, BN, BK = (4, 2 ** 10, 4) if SMOKE else (16, 2 ** 13, 16)


REFRESH_BLOCK = 8


def _post_round_reads(n: int, sampler: str,
                      eng: ClusterEngine = None) -> int:
    bn = (eng.backend.seed_tile(n, D) if eng is not None
          else choose_block_n(n, D, 1, batched=True))
    if sampler in ("tiled", "rejection"):
        return -(-n // bn) + bn
    return n


def _skip_rate(eng: ClusterEngine, res, n: int) -> float:
    """Mean fraction of tiles the bound gate skipped per round (comparable
    to the round_traffic module's skip_rate column)."""
    if res.skipped is None:
        return 0.0
    n_tiles = -(-n // eng.backend.seed_tile(n, D))
    return float(jnp.mean(res.skipped / n_tiles))


def _accept_rate(res) -> float:
    """Fraction of envelope proposals the exact ratio test accepted (1.0 for
    samplers whose every draw IS the final draw)."""
    if res.proposals is None:
        return 1.0
    props = float(jnp.sum(res.proposals))
    return float(jnp.sum(res.accepts)) / max(props, 1.0)


def _seed_reads(eng: ClusterEngine, res, n: int, k: int,
                sampler: str) -> float:
    """Modelled rows touched per SEED, straight from the run's telemetry:
    refresh-kernel rows streamed (tiles not skipped — untouched rejection
    rounds report skipped == all tiles, contributing zero) amortized over k,
    plus the per-round draw cost and, for rejection, the O(refresh_block)
    single-row exact checks."""
    tile = eng.backend.seed_tile(n, D)
    n_tiles = -(-n // tile)
    if res.skipped is not None:
        streamed = float(jnp.sum((n_tiles - res.skipped) * tile))
        if res.skipped.ndim == 2:  # batched: per-problem average
            streamed /= res.skipped.shape[0]
    else:
        streamed = float(n) * k
    reads = streamed / k + _post_round_reads(n, sampler, eng)
    if res.proposals is not None:
        extra = float(jnp.sum(res.proposals)) / k
        reads += extra * REFRESH_BLOCK  # pending-block rows per exact check
    return reads


def run(rows: list):
    key = jax.random.PRNGKey(0)
    for backend, n in (("fused", N), ("pallas", N_PALLAS)):
        pts = jnp.asarray(blobs(n, D, K, seed=0)[0])
        eng = ClusterEngine(backend)
        for sampler in ("cdf", "gumbel", "tiled", "rejection"):
            res = eng.seed(key, pts, K, sampler=sampler,
                           refresh_block=REFRESH_BLOCK)  # warms the jit too
            t = time_fn(lambda: jax.block_until_ready(
                eng.seed(key, pts, K, sampler=sampler,
                         refresh_block=REFRESH_BLOCK)))
            tms = time_ms(lambda: jax.block_until_ready(
                eng.seed(key, pts, K, sampler=sampler,
                         refresh_block=REFRESH_BLOCK)),
                interpreted=_interpreted(backend))
            rows.append({
                "bench": "seed_sampler", "backend": backend,
                "sampler": sampler, "n": n, "k": K,
                "post_round_reads": _post_round_reads(n, sampler, eng),
                "skip_rate": round(_skip_rate(eng, res, n), 4),
                "accept_rate": round(_accept_rate(res), 4),
                "seed_reads": round(_seed_reads(eng, res, n, K, sampler), 1),
                "time_ms": round(tms, 3),
                "seconds": round(t, 6),
            })


def run_rejection_vs_tiled(rows: list):
    """ISSUE 6 acceptance row: modelled rows-touched-per-seed at k=64 on a
    coherent blob layout — rejection's refresh-every-8 must come in >= 4x
    under tiled's refresh-every-round. The row keeps n = 2^16 even in smoke
    mode: the seed tile caps at 4096 rows, so any smaller n is a SINGLE tile
    and the two-level draw (hence the whole sub-linearity claim) degenerates
    to a full scan — the fused engine still runs this size in milliseconds."""
    k64, n64 = 64, 2 ** 16
    key = jax.random.PRNGKey(2)
    pts = jnp.asarray(blobs(n64, D, K, seed=2)[0])
    eng = ClusterEngine("fused")
    reads = {}
    for sampler in ("tiled", "rejection"):
        res = eng.seed(key, pts, k64, sampler=sampler,
                       refresh_block=REFRESH_BLOCK)
        t = time_fn(lambda: jax.block_until_ready(
            eng.seed(key, pts, k64, sampler=sampler,
                     refresh_block=REFRESH_BLOCK)))
        tms = time_ms(lambda: jax.block_until_ready(
            eng.seed(key, pts, k64, sampler=sampler,
                     refresh_block=REFRESH_BLOCK)))
        reads[sampler] = _seed_reads(eng, res, n64, k64, sampler)
        rows.append({
            "bench": "rejection_vs_tiled", "backend": "fused",
            "sampler": sampler, "n": n64, "k": k64,
            "post_round_reads": _post_round_reads(n64, sampler, eng),
            "skip_rate": round(_skip_rate(eng, res, n64), 4),
            "accept_rate": round(_accept_rate(res), 4),
            "seed_reads": round(reads[sampler], 1),
            "reads_ratio": 1.0 if sampler == "tiled" else
            round(reads["tiled"] / max(reads["rejection"], 1.0), 2),
            "time_ms": round(tms, 3),
            "seconds": round(t, 6),
        })


def run_batched(rows: list):
    keys = jax.random.split(jax.random.PRNGKey(1), BB)
    bpts = jnp.stack([jnp.asarray(blobs(BN, D, BK, seed=s)[0])
                      for s in range(BB)])
    for backend in ("fused", "pallas"):
        eng = ClusterEngine(backend)
        seeds = eng.seed_batched(keys, bpts, BK)
        t = time_fn(lambda: jax.block_until_ready(
            eng.kmeans_batched(keys, bpts, BK, max_iters=5)), iters=3)
        tms = time_ms(lambda: jax.block_until_ready(
            eng.kmeans_batched(keys, bpts, BK, max_iters=5)),
            interpreted=_interpreted(backend))
        rows.append({
            "bench": "kmeans_batched", "backend": backend, "sampler": "cdf",
            "n": BN, "k": BK, "post_round_reads": BB * BN,
            "skip_rate": round(_skip_rate(eng, seeds, BN), 4),
            "accept_rate": 1.0,
            "seed_reads": round(_seed_reads(eng, seeds, BN, BK, "cdf"), 1),
            "time_ms": round(tms, 3),
            "seconds": round(t, 6),
        })


def main():
    rows: list = []
    run(rows)
    run_batched(rows)
    run_rejection_vs_tiled(rows)
    header = ["bench", "backend", "sampler", "n", "k",
              "post_round_reads", "skip_rate", "accept_rate", "seed_reads",
              "time_ms", "seconds"]
    emit(rows, header)
    write_json("seed", {
        "meta": {"smoke": SMOKE, "N": N, "D": D, "K": K,
                 "batched": {"B": BB, "n": BN, "k": BK},
                 "jax_backend": jax.default_backend()},
        "rows": rows,
    })


if __name__ == "__main__":
    main()
