"""Autotuner trajectory — tuned vs default heuristic, per shape (ISSUE 8).

For each swept ``(n, k, d)`` shape the module runs the real search
(``repro.tune.search``), persists the winner into a tune cache under
``$REPRO_BENCH_OUT/tune-cache/`` (the artifact CI uploads — a pre-warmed
cache anyone can ship, see docs/engine.md "Autotuning"), and reports:

  default_*        — the heuristic geometry (`choose_block_n` block,
                     ~sqrt(n_tiles) super fan-in) and its modelled bytes
                     for one seeding round + one assignment iteration.
  tuned_*          — the searched winner and its modelled bytes.
  improvement      — default_bytes / tuned_bytes (>= 1.0; the acceptance
                     criterion needs at least one shape > 1.0).
  predicted_gap    — |analytic model − compiled-HLO accounting| /
                     HLO accounting for the DEFAULT geometry: the
                     predicted-vs-measured gap when "measured" is the
                     per-op byte extraction of ``roofline.hlo`` (the only
                     trustworthy probe off-TPU). On TPU hardware
                     ``time_ms`` additionally lands real wall clock.
  time_ms          — median-of-5 wall clock of one fused assignment round
                     (NaN off-TPU: CPU wall-clock would be reported as if
                     it measured the accelerator).

The ``cache`` section records what the run persisted (key, source,
block_n, tps), so the artifact is self-describing.

Emits BENCH_tune.json via REPRO_BENCH_OUT; benchmarks/BENCH_tune.json is
the checked-in smoke-mode baseline."""
from __future__ import annotations

import os
import pathlib

import jax

from benchmarks.common import SMOKE, emit, sweep, write_json
from repro.core import bounds as bnd
from repro.kernels.ops import choose_block_n
from repro.tune import TuneCache, measure
from repro.tune.search import resolve

SHAPES = sweep([
    (2 ** 16, 16, 8),
    (2 ** 14, 8, 2),
    (2 ** 17, 32, 16),
], smoke_take=2)


def _cache_dir() -> str | None:
    out = os.environ.get("REPRO_BENCH_OUT", "")
    if not out:
        return None
    d = pathlib.Path(out) / "tune-cache"
    d.mkdir(parents=True, exist_ok=True)
    return str(d)


def run(rows: list, cache: TuneCache):
    for n, k, d in SHAPES:
        default_bn = choose_block_n(n, d, k, batched=True)
        default_tps = bnd.tiles_per_super(-(-n // default_bn))
        default_cost = measure.model_round_cost(n, k, d, block_n=default_bn,
                                                tps=None)
        rec = resolve(cache, n=n, k=k, d=d, backend="fused",
                      dtype="float32", mode="auto")
        # model-vs-HLO gap on the default geometry: how honest is the
        # analytic byte model against XLA's actual op schedule?
        hlo = measure.hlo_round_cost(n, k, d)
        fit_model = measure.model_fit_round_bytes(n, d, k,
                                                  block_n=default_bn)
        gap = abs(fit_model - hlo["bytes"]) / max(hlo["bytes"], 1.0)
        rows.append({
            "bench": "tuned_vs_default", "backend": "fused",
            "n": n, "k": k, "d": d,
            "default_block_n": default_bn, "default_tps": default_tps,
            "tuned_block_n": rec.block_n, "tuned_tps": rec.tps,
            "default_bytes": round(float(default_cost)),
            "tuned_bytes": round(float(rec.predicted_bytes)),
            "improvement": round(float(default_cost)
                                 / max(float(rec.predicted_bytes), 1.0), 4),
            "model_fit_bytes": round(float(fit_model)),
            "hlo_fit_bytes": round(float(hlo["bytes"])),
            "predicted_gap": round(float(gap), 4),
            "source": rec.source,
            "time_ms": round(float(rec.measured_ms), 3),
        })


def run_cache(rows: list, cache: TuneCache):
    persisted = cache.save()
    for key, rec in sorted(cache.entries.items()):
        rows.append({
            "bench": "tune_cache", "backend": rec.backend,
            "n": rec.n, "k": rec.k, "d": rec.d,
            "key": key, "source": rec.source,
            "tuned_block_n": rec.block_n, "tuned_tps": rec.tps,
            "sampler": rec.sampler, "order": str(rec.order),
            "precision": rec.precision, "nprobe": rec.nprobe,
            "persisted": str(persisted) if persisted else "",
        })


def main():
    rows: list = []
    cache = TuneCache(_cache_dir())
    run(rows, cache)
    run_cache(rows, cache)
    header = ["bench", "backend", "n", "k", "d",
              "default_block_n", "default_tps", "tuned_block_n", "tuned_tps",
              "default_bytes", "tuned_bytes", "improvement",
              "model_fit_bytes", "hlo_fit_bytes", "predicted_gap",
              "key", "source", "sampler", "order", "precision", "nprobe",
              "persisted", "time_ms"]
    emit(rows, header)
    write_json("tune", {
        "meta": {"smoke": SMOKE, "shapes": [list(s) for s in SHAPES],
                 "wallclock": measure.wallclock_available(),
                 "jax_backend": jax.default_backend()},
        "rows": rows,
    })


if __name__ == "__main__":
    main()
