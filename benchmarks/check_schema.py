"""Schema check for emitted benchmark JSON (CI smoke gate).

The checked-in BENCH_*.json baselines are trajectory records other PRs diff
against; a module refactor that silently drops a column (the ISSUE 5
failure mode: a fit section without its ``prune_rate``) would corrupt the
trajectory without failing any test. This gate runs right after the CI
bench smoke and fails LOUDLY when a required per-bench column is missing
from any row of the freshly emitted JSON.

  PYTHONPATH=src python -m benchmarks.check_schema bench-out/BENCH_round.json
"""
from __future__ import annotations

import json
import pathlib
import sys

# required columns per `bench` section of each BENCH_<name>.json payload
REQUIRED: dict[str, dict[str, set]] = {
    "round": {
        "round_traffic": {"skip_rate_mean", "prune_rate", "bytes_per_round",
                          "time_ms", "seconds"},
        "skip_vs_round": {"skip_rate_mean", "prune_rate", "bytes_per_round"},
        "fit_traffic": {"skip_rate_mean", "prune_rate", "bytes_per_round",
                        "accum_hbm", "accum_hbm_flat", "time_ms",
                        "seconds"},
        "fit_skip_vs_iter": {"skip_rate_mean", "prune_rate",
                             "bytes_per_round", "accum_hbm",
                             "accum_hbm_flat"},
        "guard_overhead": {"validate", "guard_hbm", "call_hbm",
                           "guard_overhead", "time_ms", "seconds"},
    },
    "seed": {
        "seed_sampler": {"post_round_reads", "skip_rate", "accept_rate",
                         "envelope_ratio", "supers_visited", "proposal",
                         "seed_reads", "time_ms", "seconds"},
        "kmeans_batched": {"post_round_reads", "skip_rate", "accept_rate",
                           "envelope_ratio", "supers_visited", "proposal",
                           "seed_reads", "time_ms", "seconds"},
        "rejection_vs_tiled": {"post_round_reads", "skip_rate",
                               "accept_rate", "envelope_ratio",
                               "supers_visited", "proposal",
                               "refresh_block", "seed_reads", "reads_ratio",
                               "time_ms", "seconds"},
        "hier_vs_flat": {"layout", "proposal", "refresh_block",
                         "post_round_reads", "skip_rate", "accept_rate",
                         "envelope_ratio", "supers_visited", "seed_reads",
                         "reads_ratio", "hier_over_flat", "time_ms",
                         "seconds"},
    },
    "tune": {
        "tuned_vs_default": {"n", "k", "d", "default_block_n",
                             "default_tps", "tuned_block_n", "tuned_tps",
                             "default_bytes", "tuned_bytes", "improvement",
                             "model_fit_bytes", "hlo_fit_bytes",
                             "predicted_gap", "source", "time_ms"},
        "tune_cache": {"key", "source", "tuned_block_n", "tuned_tps",
                       "sampler", "order", "precision", "nprobe"},
    },
    "ivf": {
        "ivf_scan": {"layout", "nlist", "nprobe", "probed_tiles_mean",
                     "gate_skip_rate", "bytes_per_query",
                     "bytes_per_query_nogate", "bytes_full", "bytes_ratio",
                     "recall_at10", "recall_at10_nogate", "time_ms",
                     "seconds"},
        "ivf_adc": {"nlist", "nprobe", "n_sub", "probed_tiles_mean",
                    "bytes_per_query", "bytes_exact", "bytes_ratio",
                    "recall_at10", "time_ms", "seconds"},
    },
}


def check_payload(name: str, payload: dict) -> list[str]:
    """Returns a list of human-readable schema violations (empty = clean)."""
    errors = []
    rules = REQUIRED.get(name)
    if rules is None:
        return errors
    rows = payload.get("rows")
    if not rows:
        return [f"BENCH_{name}: no rows emitted"]
    seen = set()
    for i, row in enumerate(rows):
        bench = row.get("bench")
        seen.add(bench)
        missing = rules.get(bench, set()) - row.keys()
        if missing:
            errors.append(f"BENCH_{name} row {i} (bench={bench!r}): "
                          f"missing {sorted(missing)}")
    absent_sections = set(rules) - seen
    if absent_sections:
        errors.append(f"BENCH_{name}: sections never emitted: "
                      f"{sorted(absent_sections)}")
    return errors


def check_file(path: pathlib.Path) -> list[str]:
    name = path.name.removeprefix("BENCH_").removesuffix(".json")
    try:
        payload = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    return check_payload(name, payload)


def main() -> None:
    paths = [pathlib.Path(p) for p in sys.argv[1:]]
    if not paths:
        print("usage: python -m benchmarks.check_schema BENCH_*.json ...",
              file=sys.stderr)
        raise SystemExit(2)
    errors = []
    for p in paths:
        errors += check_file(p)
    if errors:
        print("BENCH SCHEMA CHECK FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        raise SystemExit(1)
    print(f"bench schema ok: {', '.join(p.name for p in paths)}")


if __name__ == "__main__":
    main()
