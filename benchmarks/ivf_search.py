"""IVF search traffic — bytes-per-query and recall vs nprobe (ISSUE 10).

The serving claim: a label-sorted layout makes query cost scale with
``nprobe/nlist`` instead of ``n``, and the kth-distance tile gate skips
additional traffic at ZERO recall change (it is a value-noop — the scan's
results are bitwise identical with the gate off). This module measures the
modelled HBM traffic per query under the byte accounting the round/seed
benchmarks use (counting what the scan actually streams):

  routing          (n_super + nlist) centroid rows + their norms/radii,
                   per query — the price of EXACT top-nprobe routing.
  ball summaries   (d+1)*4 bytes per PROBED tile (read even when the gate
                   then skips the tile — the gate reads the ball to decide).
  row stream       block_n*(d+1)*4 bytes per SCANNED tile (probed minus
                   gate-skipped): rows + cached norms. The ADC path streams
                   block_n*(n_sub + 8) instead (uint8 codes + int32 list id
                   + fp32 ||x_hat||^2) plus a resident per-query LUT.

Sections:

  ivf_scan  layout in {label, none} x nprobe sweep: probed tiles,
            gate skip rate, bytes_per_query (and with the gate off),
            bytes_ratio vs a brute-force scan of all n rows, recall@10
            both gated and ungated (always equal — the value-noop check
            rides along in every row), wall clock.
  ivf_adc   same sweep on the PQ index: ADC bytes vs the exact path at the
            same nprobe, recall@10 of reconstructed-distance ranking.

Acceptance hooks: bytes_ratio >= 4 at nprobe = nlist/8 on the label
layout; gate_skip_rate > 0 with recall_at10 == recall_at10_nogate.

Emits BENCH_ivf.json via REPRO_BENCH_OUT; benchmarks/BENCH_ivf.json is the
checked-in smoke-mode baseline."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SMOKE, emit, sweep, time_ms, write_json
from repro.data.synthetic import blobs
from repro.serve.ivf import IvfIndex

# (n, d, nlist, n_queries)
SHAPES = sweep([
    (4096, 16, 32, 32),
    (65536, 32, 64, 64),
], smoke_take=1)

K = 10
N_SUB = 4


def _nprobes(nlist: int) -> list[int]:
    return sorted({max(1, nlist // f) for f in (1, 2, 4, 8)}, reverse=True)


def _recall(found: np.ndarray, truth: np.ndarray) -> float:
    hits = sum(len(set(found[q]) & set(truth[q])) for q in range(len(truth)))
    return hits / truth.size


def _scan_bytes(idx: IvfIndex, res, *, row_unit: float,
                resident: float = 0.0) -> float:
    """Modelled per-query HBM bytes for one search: routing + probed-tile
    ball summaries + the row stream over tiles the gate let through."""
    d = idx.points.shape[1]
    n_sup = idx.super_centers.shape[0]
    route = (n_sup * (d + 2) + idx.nlist * (d + 1)) * 4.0
    probed = float(np.mean(np.asarray(res.probed_tiles)))
    scanned = probed - float(np.mean(np.asarray(res.gate_skipped)))
    balls = probed * (d + 1) * 4.0
    return route + resident + balls + scanned * idx.block_n * row_unit


def run_scan(rows: list) -> None:
    for n, d, nlist, n_q in SHAPES:
        pts, _ = blobs(n, d, nlist, seed=0)
        queries = jnp.asarray(blobs(n_q, d, nlist, seed=1)[0])
        indexes = {
            "label": IvfIndex.build(jnp.asarray(pts), nlist, layout="label"),
            "none": IvfIndex.build(jnp.asarray(pts), nlist, layout="none"),
        }
        truth = np.asarray(indexes["label"].exhaustive(queries, K)[0])
        bytes_full = n * (d + 1) * 4.0
        for layout, idx in indexes.items():
            for nprobe in _nprobes(nlist):
                t0 = time.time()
                res = idx.search(queries, K, nprobe=nprobe, gate=True)
                off = idx.search(queries, K, nprobe=nprobe, gate=False)
                unit = (d + 1) * 4.0
                bq = _scan_bytes(idx, res, row_unit=unit)
                bq_off = _scan_bytes(idx, off, row_unit=unit)
                probed = float(np.mean(np.asarray(res.probed_tiles)))
                skip = (float(np.mean(np.asarray(res.gate_skipped)))
                        / max(probed, 1.0))
                ms = time_ms(
                    lambda: idx.search(queries, K, nprobe=nprobe,
                                       backend="fused"))
                rows.append({
                    "bench": "ivf_scan", "layout": layout,
                    "n": n, "d": d, "nlist": nlist, "nprobe": nprobe,
                    "block_n": idx.block_n, "n_tiles": idx.n_tiles,
                    "probed_tiles_mean": round(probed, 2),
                    "gate_skip_rate": round(skip, 4),
                    "bytes_per_query": round(bq),
                    "bytes_per_query_nogate": round(bq_off),
                    "bytes_full": round(bytes_full),
                    "bytes_ratio": round(bytes_full / max(bq, 1.0), 2),
                    "recall_at10": round(
                        _recall(np.asarray(res.indices), truth), 4),
                    "recall_at10_nogate": round(
                        _recall(np.asarray(off.indices), truth), 4),
                    "time_ms": round(ms, 3),
                    "seconds": round(time.time() - t0, 2),
                })


def run_adc(rows: list) -> None:
    for n, d, nlist, n_q in SHAPES:
        pts, _ = blobs(n, d, nlist, seed=0)
        queries = jnp.asarray(blobs(n_q, d, nlist, seed=1)[0])
        idx = IvfIndex.build(jnp.asarray(pts), nlist, pq_nsub=N_SUB)
        truth = np.asarray(idx.exhaustive(queries, K)[0])
        n_codes = idx.pq.codebook.centroids.shape[1]
        resident = (N_SUB * n_codes + nlist) * 4.0     # per-query LUT+qdots
        for nprobe in _nprobes(nlist):
            t0 = time.time()
            res = idx.search(queries, K, nprobe=nprobe, mode="adc")
            exact = idx.search(queries, K, nprobe=nprobe, mode="exact")
            adc_unit = N_SUB * 1.0 + 8.0               # codes + label + u
            bq = _scan_bytes(idx, res, row_unit=adc_unit, resident=resident)
            bq_exact = _scan_bytes(idx, exact, row_unit=(d + 1) * 4.0)
            ms = time_ms(
                lambda: idx.search(queries, K, nprobe=nprobe, mode="adc",
                                   backend="fused"))
            rows.append({
                "bench": "ivf_adc", "layout": "label",
                "n": n, "d": d, "nlist": nlist, "nprobe": nprobe,
                "n_sub": N_SUB,
                "probed_tiles_mean": round(
                    float(np.mean(np.asarray(res.probed_tiles))), 2),
                "bytes_per_query": round(bq),
                "bytes_exact": round(bq_exact),
                "bytes_ratio": round(bq_exact / max(bq, 1.0), 2),
                "recall_at10": round(
                    _recall(np.asarray(res.indices), truth), 4),
                "time_ms": round(ms, 3),
                "seconds": round(time.time() - t0, 2),
            })


def main():
    rows: list = []
    run_scan(rows)
    run_adc(rows)
    header = ["bench", "layout", "n", "d", "nlist", "nprobe", "n_sub",
              "block_n", "n_tiles", "probed_tiles_mean", "gate_skip_rate",
              "bytes_per_query", "bytes_per_query_nogate", "bytes_exact",
              "bytes_full", "bytes_ratio", "recall_at10",
              "recall_at10_nogate", "time_ms", "seconds"]
    emit(rows, header)
    write_json("ivf", {
        "meta": {"smoke": SMOKE, "k": K, "n_sub": N_SUB,
                 "shapes": [list(s) for s in SHAPES],
                 "jax_backend": jax.default_backend()},
        "rows": rows,
    })


if __name__ == "__main__":
    main()
