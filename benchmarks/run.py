"""Benchmark harness — one module per paper table/figure + quality + roofline.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig1 fig3  # subset
"""
from __future__ import annotations

import sys
import time

from benchmarks import (autotune, dist_scaling, fig1_global, fig2_constant,
                        fig3_texture, ivf_search, minibatch, quality_parity,
                        roofline, round_traffic, seed_sampling)

MODULES = {
    "fig1": fig1_global,
    "fig2": fig2_constant,
    "fig3": fig3_texture,
    "quality": quality_parity,
    "dist": dist_scaling,
    "minibatch": minibatch,
    "roofline": roofline,
    "seed": seed_sampling,
    "round": round_traffic,
    "tune": autotune,
    "ivf": ivf_search,
}


def main() -> None:
    which = sys.argv[1:] or list(MODULES)
    for name in which:
        mod = MODULES[name]
        print(f"\n===== {name} ({mod.__name__}) =====")
        t0 = time.time()
        mod.main()
        print(f"===== {name} done in {time.time() - t0:.1f}s =====")


if __name__ == "__main__":
    main()
