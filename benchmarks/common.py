"""Shared benchmark utilities: wall-clock timing of jitted fns + CSV output.

Set REPRO_BENCH_SMOKE=1 to shrink every sweep to its smallest point (the CI
smoke mode — each module finishes in seconds while still exercising the full
code path)."""
from __future__ import annotations

import os
import time
from typing import Callable, Sequence

import jax

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def sweep(values: Sequence, smoke_take: int = 1) -> list:
    """A benchmark sweep, cut to its first `smoke_take` points in smoke mode."""
    vals = list(values)
    return vals[:smoke_take] if SMOKE else vals


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(rows: list[dict], header: list[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
