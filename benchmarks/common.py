"""Shared benchmark utilities: wall-clock timing of jitted fns + CSV output.

Set REPRO_BENCH_SMOKE=1 to shrink every sweep to its smallest point (the CI
smoke mode — each module finishes in seconds while still exercising the full
code path). Set REPRO_BENCH_OUT=<dir> to additionally capture JSON payloads
from the modules that emit them via `write_json` (the `seed` module's
BENCH_seed.json, the `round` module's BENCH_round.json and the `tune`
module's BENCH_tune.json — the CI workflow uploads that directory as an
artifact; the same-named files under benchmarks/ are the checked-in
baselines)."""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Callable, Optional, Sequence

import jax

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def write_json(name: str, payload: dict) -> Optional[pathlib.Path]:
    """Write a module's benchmark payload to $REPRO_BENCH_OUT/BENCH_<name>.json
    (no-op when the env var is unset)."""
    out_dir = os.environ.get("REPRO_BENCH_OUT", "")
    if not out_dir:
        return None
    p = pathlib.Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    path = p / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"[bench] wrote {path}")
    return path


def sweep(values: Sequence, smoke_take: int = 1) -> list:
    """A benchmark sweep, cut to its first `smoke_take` points in smoke mode."""
    vals = list(values)
    return vals[:smoke_take] if SMOKE else vals


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_ms(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            interpreted: bool = False) -> float:
    """Median wall-time in MILLISECONDS (median-of-`iters` after `warmup`
    discarded runs), or NaN when the timed path runs in Pallas interpret
    mode (`interpreted=True`) — interpreter wall-clock would be reported
    as if it measured the kernel, which is worse than no number."""
    if interpreted:
        return float("nan")
    return 1000.0 * time_fn(fn, *args, warmup=warmup, iters=iters)


def emit(rows: list[dict], header: list[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
