"""§Roofline benchmark — reads artifacts/dryrun/*.json (produced by
repro.launch.dryrun) and emits the per-(arch x shape x mesh) roofline terms."""
from __future__ import annotations

from benchmarks.common import emit
from repro.roofline.report import csv_rows


def run(rows: list):
    got = csv_rows()
    if not got:
        rows.append({"arch": "(no artifacts — run "
                             "`python -m repro.launch.dryrun` first)"})
        return
    rows.extend({"bench": "roofline", **r} for r in got)


def main():
    rows = []
    run(rows)
    emit(rows, ["bench", "arch", "shape", "mesh", "compute_s", "memory_s",
                "collective_s", "bound_s", "dominant", "useful_ratio",
                "mfu_bound", "roofline_fraction"])


if __name__ == "__main__":
    main()
