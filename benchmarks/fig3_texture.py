"""Paper Fig. 3 — texture-memory variant: points in the read-only cached path.

TPU analogue (DESIGN.md §2): points STREAMED through the Pallas pipeline and
read exactly once by a fused min-update+partial-sum pass, vs the two-pass
global variant that writes min_d2 to HBM and re-reads it for the reduction.
The paper reports 10-14% over global memory; the fused single-pass removes
one full (n,) read + the separate kernel dispatch — same order of saving.
Measured through the ClusterEngine 'global' vs 'fused' backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, sweep, time_fn
from repro.core.engine import ClusterEngine
from repro.data.synthetic import blobs

N_SWEEP = [2 ** 14, 2 ** 15, 2 ** 16, 2 ** 17]
K = 50

GLOBAL = ClusterEngine("global")
FUSED = ClusterEngine("fused")


def run(rows: list):
    key = jax.random.PRNGKey(0)
    for n in sweep(N_SWEEP):
        pts = jnp.asarray(blobs(n, 2, K, seed=0)[0])
        t_glob = time_fn(lambda: GLOBAL.seed(key, pts, K), warmup=1, iters=3)
        t_fused = time_fn(lambda: FUSED.seed(key, pts, K), warmup=1, iters=3)
        gain = 100.0 * (t_glob - t_fused) / t_glob
        rows.append({"bench": "fig3_streamed_vs_global", "n": n, "k": K,
                     "global_s": f"{t_glob:.4f}", "streamed_s": f"{t_fused:.4f}",
                     "gain_pct": f"{gain:.1f}"})
        # single-pass reads each point once; two-pass re-reads min_d2:
        d = 2
        one_pass = n * d * 4 + 2 * n * 4
        two_pass = n * d * 4 + 4 * n * 4
        rows.append({"bench": "fig3_hbm_traffic_model", "n": n, "k": K,
                     "global_s": two_pass, "streamed_s": one_pass,
                     "gain_pct": f"{100 * (two_pass - one_pass) / two_pass:.1f}"})


def main():
    rows = []
    run(rows)
    emit(rows, ["bench", "n", "k", "global_s", "streamed_s", "gain_pct"])


if __name__ == "__main__":
    main()
