"""Beyond-paper: multi-device weak-scaling of the distributed seeding
(the paper stops at 1 GPU; this is the pod-level design). Runs in a
subprocess-free way IF the process was started with multiple fake devices;
otherwise reports the collective-volume model (bytes/round, device count)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.data.synthetic import blobs


def run(rows: list):
    n_dev = jax.device_count()
    if n_dev >= 4:
        from repro.core.distributed import mesh_engine
        mesh = jax.make_mesh((n_dev,), ("data",))
        eng = mesh_engine(mesh, "data")
        for n in (2 ** 14, 2 ** 16):
            pts = jnp.asarray(blobs(n, 2, 50, seed=0)[0])
            t = time_fn(lambda: eng.seed(jax.random.PRNGKey(0), pts, 50),
                        warmup=1, iters=3)
            rows.append({"bench": "dist_seeding", "n": n, "devices": n_dev,
                         "seconds": f"{t:.4f}"})
    # collective model: per seeding round, independent of N
    for k, d, dev in ((50, 2, 256), (256, 128, 256), (4096, 128, 512)):
        per_round = 4 + 4 + d * 4          # psum(phi) + argmax pair + winner row
        rows.append({"bench": "dist_collective_model", "n": f"k={k},d={d}",
                     "devices": dev, "seconds": f"{per_round * k}B_total"})


def main():
    rows = []
    run(rows)
    emit(rows, ["bench", "n", "devices", "seconds"])


if __name__ == "__main__":
    main()
