"""IVF search (ISSUE 10 tentpole): exactness, gating, ADC, and telemetry.

The load-bearing claims, each pinned bitwise where the design promises
bitwise: the exact path at ``nprobe == nlist`` IS brute force (all three
scan backends), the kth-distance tile gate is a value-noop, the Pallas
kernels and their pure-jnp twins are bit-identical on arbitrary probe
maps, ADC equals decode-then-exact within fp tolerance, and the offset /
counter contracts hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bounds, telemetry
from repro.core.guards import InvalidInputError
from repro.core.topk import IDX_SENTINEL, init_topk, lex_topk, merge_topk
from repro.data.ordering import label_sort_order
from repro.data.synthetic import blobs
from repro.kernels import ops as kops
from repro.kernels.ref import ivf_bruteforce_topk, ivf_scan_ref
from repro.serve import IvfIndex, default_nprobe, kvquant

BACKENDS = ("reference", "fused", "pallas")


def _eq(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.fixture(scope="module")
def index():
    pts, _ = blobs(4000, 16, 32, seed=0)
    return IvfIndex.build(jnp.asarray(pts), 32, block_n=128)


@pytest.fixture(scope="module")
def queries():
    return jnp.asarray(blobs(48, 16, 32, seed=1)[0])


# ---------------------------------------------------------------------------
# exactness: nprobe == nlist is brute force, bitwise, on every backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_full_probe_is_bruteforce_bitwise(index, queries, backend):
    ei, ev = index.exhaustive(queries, 10)
    r = index.search(queries, 10, nprobe=index.nlist, backend=backend)
    _eq(r.indices, ei)
    _eq(r.dists, ev)


def test_backends_agree_bitwise_at_partial_probe(index, queries):
    outs = [index.search(queries, 10, nprobe=8, backend=be)
            for be in BACKENDS]
    for r in outs[1:]:
        _eq(r.indices, outs[0].indices)
        _eq(r.dists, outs[0].dists)
        _eq(r.gate_skipped, outs[0].gate_skipped)


def test_scattered_layout_still_exact_at_full_probe():
    pts, _ = blobs(2000, 8, 16, seed=2)
    idx = IvfIndex.build(jnp.asarray(pts), 16, block_n=128, layout="none")
    # layout='none' keeps caller order: perm is the identity
    _eq(idx.perm, jnp.arange(2000, dtype=jnp.int32))
    qs = jnp.asarray(blobs(16, 8, 16, seed=3)[0])
    ei, ev = idx.exhaustive(qs, 5)
    r = idx.search(qs, 5, nprobe=16)
    _eq(r.indices, ei)
    _eq(r.dists, ev)


def test_k_exceeding_n_pads_with_sentinels():
    pts, _ = blobs(300, 4, 4, seed=5)
    idx = IvfIndex.build(jnp.asarray(pts), 4, block_n=128)
    qs = jnp.asarray(blobs(3, 4, 4, seed=6)[0])
    r = idx.search(qs, 310, nprobe=4)
    assert r.indices.shape == (3, 310)
    assert np.all(np.asarray(r.indices[:, 300:]) == IDX_SENTINEL)
    assert np.all(np.isinf(np.asarray(r.dists[:, 300:])))
    ei, ev = idx.exhaustive(qs, 310)
    _eq(r.indices, ei)
    _eq(r.dists, ev)


@pytest.mark.parametrize("nlist", [5, 20])
def test_routing_exact_at_partial_probe_for_non_pow2_nlist(nlist):
    # non-pow2 nlist is where the build-time pow2 super-group size differs
    # from a naive ceil(nlist/n_sup) rederivation; routing must still
    # probe exactly the true top-nprobe centroids
    from repro.serve import ivf as ivf_mod

    pts, _ = blobs(3000, 12, nlist, seed=12)
    idx = IvfIndex.build(jnp.asarray(pts), nlist, block_n=128)
    qs = jnp.asarray(blobs(24, 12, nlist, seed=13)[0])
    qn = jnp.sum(qs * qs, axis=1)
    cd2 = np.asarray(jnp.maximum(
        qn[:, None] - 2.0 * (qs @ idx.centroids.T)
        + idx.centroid_norms[None, :], 0.0))
    for nprobe in (1, 2, nlist // 2, nlist):
        probed, _ = ivf_mod._route(
            qs, idx.centroids, idx.centroid_norms, idx.super_centers,
            idx.super_radii, idx.super_sizes, nprobe=nprobe)
        p = np.asarray(probed)
        assert np.all(p.sum(axis=1) == nprobe)
        true = np.argsort(cd2, axis=1)[:, :nprobe]
        assert np.all(np.take_along_axis(p, true, axis=1))
    # and the end-to-end exactness anchor holds at full probe
    ei, ev = idx.exhaustive(qs, 7)
    r = idx.search(qs, 7, nprobe=nlist)
    _eq(r.indices, ei)
    _eq(r.dists, ev)


# ---------------------------------------------------------------------------
# recall at partial probe on clustered data
# ---------------------------------------------------------------------------


def test_recall_at_quarter_probe(index, queries):
    ei, _ = index.exhaustive(queries, 10)
    r = index.search(queries, 10, nprobe=index.nlist // 4)
    ei, ri = np.asarray(ei), np.asarray(r.indices)
    recall = np.mean([len(set(ri[q]) & set(ei[q])) / 10
                      for q in range(ri.shape[0])])
    assert recall >= 0.95, recall
    # partial probing actually probes partially
    assert np.asarray(r.probed_tiles).max() < index.n_tiles


# ---------------------------------------------------------------------------
# the kth-distance tile gate: skips traffic, never values
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nprobe", [8, 32])
def test_gate_is_value_noop(index, queries, nprobe):
    gated = index.search(queries, 10, nprobe=nprobe, gate=True)
    plain = index.search(queries, 10, nprobe=nprobe, gate=False)
    _eq(gated.indices, plain.indices)
    _eq(gated.dists, plain.dists)
    assert np.all(np.asarray(plain.gate_skipped) == 0)


def test_gate_fires_on_clustered_data(index, queries):
    r = index.search(queries, 10, nprobe=index.nlist, gate=True)
    assert int(np.asarray(r.gate_skipped).sum()) > 0


# ---------------------------------------------------------------------------
# kernel twins: pallas == pure-jnp ref, bitwise, on arbitrary probe maps
# ---------------------------------------------------------------------------


def test_scan_kernel_matches_ref_bitwise_on_random_probe_maps():
    rng = np.random.default_rng(7)
    n, d, Q, k, bn = 900, 8, 5, 6, 128
    pts = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    rc = bounds.prologue(pts, bn)
    grid = -(-n // bn)
    active = jnp.asarray(rng.random((Q, grid)) < 0.5)
    ids, nact = jax.vmap(bounds.compact_ids)(active)
    for gate in (True, False):
        a = kops.ivf_scan(qs, pts, rc.norms, rc.centers, rc.radii, ids,
                          nact, k=k, block_n=bn, gate=gate)
        b = ivf_scan_ref(qs, pts, rc.norms, rc.centers, rc.radii, ids,
                         nact, k=k, block_n=bn, gate=gate)
        for x, y in zip(a, b):
            _eq(x, y)


def test_adc_kernel_matches_ref_bitwise(index, queries):
    pts, _ = blobs(1500, 8, 8, seed=8)
    idx = IvfIndex.build(jnp.asarray(pts), 8, block_n=128, pq_nsub=4)
    qs = jnp.asarray(blobs(6, 8, 8, seed=9)[0])
    outs = [idx.search(qs, 5, nprobe=8, mode="adc", backend=b)
            for b in ("pallas", "fused")]
    _eq(outs[0].indices, outs[1].indices)
    _eq(outs[0].dists, outs[1].dists)
    _eq(outs[0].gate_skipped, outs[1].gate_skipped)


# ---------------------------------------------------------------------------
# ADC path: exact distances to the PQ reconstruction
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pq_index():
    pts, _ = blobs(4000, 16, 32, seed=0)
    return IvfIndex.build(jnp.asarray(pts), 32, block_n=128, pq_nsub=4)


def test_adc_equals_decode_then_exact(pq_index, queries):
    r = pq_index.search(queries, 10, nprobe=pq_index.nlist, mode="adc")
    xhat = (kvquant.decode(pq_index.pq.codes,
                           pq_index.pq.codebook).astype(jnp.float32)
            + pq_index.centroids[pq_index.labels])
    ev, ei = ivf_bruteforce_topk(queries, xhat, bounds.point_norms(xhat),
                                 k=10)
    np.testing.assert_allclose(np.asarray(r.dists), np.asarray(ev),
                               rtol=1e-4, atol=1e-4)
    _eq(r.indices, np.asarray(pq_index.perm)[np.asarray(ei)])


def test_adc_gate_is_value_noop(pq_index, queries):
    a = pq_index.search(queries, 10, nprobe=8, mode="adc", gate=True)
    b = pq_index.search(queries, 10, nprobe=8, mode="adc", gate=False)
    _eq(a.indices, b.indices)
    _eq(a.dists, b.dists)


def test_adc_requires_pq_storage(index, queries):
    with pytest.raises(InvalidInputError, match="pq_nsub"):
        index.search(queries, 5, mode="adc")


# ---------------------------------------------------------------------------
# telemetry + offsets + entry guards
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nprobe", [4, 16, 32])
def test_counter_contract(index, queries, nprobe):
    r = index.search(queries, 10, nprobe=nprobe)
    telemetry.check_ivf_counters(
        r.probed_lists, r.probed_tiles, r.gate_skipped,
        n_queries=queries.shape[0], nlist=index.nlist,
        n_tiles=index.n_tiles)
    assert np.all(np.asarray(r.probed_lists) <= nprobe)


def test_label_sort_order_offsets():
    labels = jnp.asarray(np.random.default_rng(0).integers(0, 5, 200)
                         .astype(np.int32))
    perm, inv, starts, counts = label_sort_order(labels, nlist=5,
                                                 return_offsets=True)
    _eq(starts, jnp.cumsum(counts) - counts)
    assert int(counts.sum()) == 200
    srt = np.asarray(labels)[np.asarray(perm)]
    for l in range(5):
        s, c = int(starts[l]), int(counts[l])
        assert np.all(srt[s:s + c] == l)
    # historical 2-tuple shape untouched; offsets demand a static nlist
    assert len(label_sort_order(labels)) == 2
    with pytest.raises(ValueError, match="nlist"):
        label_sort_order(labels, return_offsets=True)


def test_build_and_search_validate_guards(index):
    pts, _ = blobs(500, 8, 4, seed=10)
    bad = np.asarray(pts).copy()
    bad[3] = np.nan
    with pytest.raises(InvalidInputError, match="non-finite"):
        IvfIndex.build(bad, 4)
    idx = IvfIndex.build(jnp.asarray(pts), 4, block_n=128)
    badq = np.zeros((2, 8), np.float32)
    badq[0, 0] = np.inf
    with pytest.raises(InvalidInputError, match="non-finite"):
        idx.search(badq, 3)
    r = idx.search(np.asarray(badq), 3, validate="sanitize")
    assert np.isfinite(np.asarray(r.dists)).all()
    with pytest.raises(InvalidInputError, match="layout"):
        IvfIndex.build(jnp.asarray(pts), 4, layout="zorder")
    with pytest.raises(InvalidInputError, match="mode"):
        index.search(jnp.zeros((1, 16)), 3, mode="fuzzy")


def test_kvquant_entry_guards():
    key = jax.random.PRNGKey(0)
    vecs = jnp.asarray(np.random.default_rng(1).normal(size=(256, 8))
                       .astype(np.float32))
    with pytest.raises(InvalidInputError, match="n_sub"):
        kvquant.build_codebook(key, vecs, n_sub=3)
    bad = np.asarray(vecs).copy()
    bad[0, 0] = np.nan
    with pytest.raises(InvalidInputError, match="non-finite"):
        kvquant.build_codebook(key, bad, n_sub=4)
    cb = kvquant.build_codebook(key, vecs, n_sub=4, n_codes=16)
    with pytest.raises(InvalidInputError, match="dimension"):
        kvquant.encode(jnp.zeros((2, 6)), cb)
    with pytest.raises(InvalidInputError, match="n_sub"):
        kvquant.decode(jnp.zeros((2, 3), jnp.uint8), cb)
    empty = kvquant.PQCodebook(jnp.zeros((0, 0, 0)))
    with pytest.raises(InvalidInputError, match="codebook"):
        kvquant.encode(vecs, empty)
    with pytest.raises(InvalidInputError, match="policy"):
        kvquant.encode(vecs, cb, validate="lenient")
    # sanitize zeroes the poisoned row and round-trips
    codes = kvquant.encode(bad, cb, validate="sanitize")
    assert codes.shape == (256, 4)


def test_default_nprobe_heuristic_and_advisory(tmp_path, monkeypatch):
    from repro import tune

    monkeypatch.delenv("REPRO_TUNE_CACHE", raising=False)
    assert default_nprobe(4000, 32, 16) == 4
    assert default_nprobe(4000, 4, 16) == 1
    # a persisted advisory record wins over the heuristic
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    cache = tune.TuneCache(str(tmp_path))
    cache.put(tune.TuneRecord(n=4000, k=32, d=16, backend="ivf",
                              dtype="float32", nprobe=12))
    cache.save()
    assert default_nprobe(4000, 32, 16) == 12


# ---------------------------------------------------------------------------
# the lexicographic top-k primitive: blocked merge == global sort
# ---------------------------------------------------------------------------


def test_merge_topk_is_blocking_invariant():
    rng = np.random.default_rng(11)
    vals = jnp.asarray(rng.random(257).astype(np.float32))
    idxs = jnp.arange(257, dtype=jnp.int32)
    want = lex_topk(vals, idxs, 9)
    tv, ti = init_topk(9)
    for lo in range(0, 257, 64):     # uneven final block on purpose
        tv, ti = merge_topk(tv, ti, vals[lo:lo + 64], idxs[lo:lo + 64], 9)
    _eq(tv, want[0])
    _eq(ti, want[1])


def test_lex_topk_breaks_ties_by_index():
    vals = jnp.asarray([1.0, 0.5, 0.5, 2.0], jnp.float32)
    idxs = jnp.asarray([3, 2, 1, 0], jnp.int32)
    tv, ti = lex_topk(vals, idxs, 2)
    _eq(ti, jnp.asarray([1, 2], jnp.int32))
