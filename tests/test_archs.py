"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates its reduced config and runs forward + one train step on CPU,
asserting output shapes and no NaNs; decode is checked against full prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, get_config, supported_shapes
from repro.models.registry import get_model
from repro.optim import AdamWConfig
from repro.launch.step import init_train_state, make_train_step


def _batch(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        vm = jnp.zeros((B, S), bool).at[:, :cfg.vision_tokens].set(True)
        batch["vision_mask"] = vm
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (B, 3, S)).astype(jnp.int32)
    if cfg.family == "encdec":
        batch["encoder_feats"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_decreases_nothing_nan(arch):
    cfg = get_config(arch, smoke=True)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                    decay_steps=10)))
    batch = _batch(cfg)
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses   # same batch: must overfit


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_prefill(arch):
    """Last-token logits from (prefill S-1 + decode 1) == full prefill S."""
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    S = 16
    batch = _batch(cfg, B=2, S=S, seed=2)
    batch.pop("labels")
    full_logits, _ = model.prefill(params, batch)

    pre = {k: (v[:, : S - 1] if k in ("tokens", "vision_mask") else v)
           for k, v in batch.items()}
    if "positions" in pre:
        pre["positions"] = batch["positions"][:, :, : S - 1]
    _, cache = model.prefill(params, pre, cache_len=S + 4)
    kw = {}
    if cfg.family == "vlm":
        kw["positions"] = jnp.full((2, 3, 1), S - 1, jnp.int32)
    dec_logits, cache2 = model.decode_step(
        params, batch["tokens"][:, S - 1:S], cache, **kw)
    diff = float(jnp.max(jnp.abs(full_logits.astype(jnp.float32)
                                 - dec_logits.astype(jnp.float32))))
    assert diff < 0.06, f"{arch}: prefill/decode mismatch {diff}"
    assert int(cache2["pos"]) == S


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_shape_support_matrix(arch):
    cfg = get_config(arch)
    shapes = supported_shapes(cfg)
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
    if arch in ("zamba2-1.2b", "rwkv6-7b"):
        assert "long_500k" in shapes     # sub-quadratic families
    else:
        assert "long_500k" not in shapes


def test_full_configs_match_assignment():
    """The exact figures from the assignment brief."""
    spec = {
        "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32, d_ff=8192,
                            vocab=32000, ssm_state=64),
        "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32,
                            n_kv_heads=32, d_ff=11008, vocab=102400),
        "gemma2-2b": dict(n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
                          d_ff=9216, vocab=256000),
        "granite-8b": dict(n_layers=36, d_model=4096, n_heads=32,
                           n_kv_heads=8, d_ff=14336, vocab=49152),
        "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=32, d_ff=13440, vocab=92416),
        "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                                 n_kv_heads=20, d_ff=5120, vocab=51866),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                     n_kv_heads=8, moe_d_ff=6400, vocab=32064,
                                     n_experts=16, n_experts_per_tok=2),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                n_kv_heads=16, moe_d_ff=1408, vocab=151936,
                                n_experts=60, n_experts_per_tok=4),
        "qwen2-vl-7b": dict(n_layers=28, d_model=3584, n_heads=28,
                            n_kv_heads=4, d_ff=18944, vocab=152064),
        "rwkv6-7b": dict(n_layers=32, d_model=4096, d_ff=14336, vocab=65536),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for f, v in fields.items():
            assert getattr(cfg, f) == v, (arch, f, getattr(cfg, f), v)


def test_moe_chunked_dispatch_equivalence():
    """Chunked MoE dispatch == single-shot dispatch when capacity is ample."""
    import dataclasses
    from repro.models import moe as MOE
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    cfg_big = dataclasses.replace(cfg, capacity_factor=8.0, moe_chunk=0)
    cfg_chunk = dataclasses.replace(cfg, capacity_factor=8.0, moe_chunk=32)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32).astype(cfg.compute_dtype)
    y1, _ = MOE.moe_apply(p, x, cfg_big)
    y2, _ = MOE.moe_apply(p, x, cfg_chunk)
    # bf16 compute: chunked dispatch reorders accumulations -> ~1 ulp noise
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=6e-2)


def test_kmeans_router_init_balances():
    """Paper integration #2: k-means++ router init beats random on balance."""
    from repro.models import moe as MOE
    from repro.core.quality import balance
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    key = jax.random.PRNGKey(0)
    # clustered token embeddings (realistic: token embeds live on a manifold)
    from repro.data.synthetic import blobs
    emb, _ = blobs(2048, cfg.d_model, cfg.n_experts, seed=1, spread=0.3)
    emb = jnp.asarray(emb)
    p = MOE.moe_init(key, cfg)
    p_km = MOE.kmeans_router_init(jax.random.PRNGKey(2), p, emb, cfg)

    def top1_balance(router):
        logits = emb @ router
        a = jnp.argmax(logits, axis=-1)
        return float(balance(a, cfg.n_experts))

    b_rand = top1_balance(p["router"])
    b_km = top1_balance(p_km["router"])
    assert b_km <= b_rand * 1.05, (b_km, b_rand)
