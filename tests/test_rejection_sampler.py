"""Deterministic acceptance tests for sampler='rejection' (ISSUE 6): the
shared-uniform-stream bitwise pin against sampler='tiled', the two-sample
chi-square distribution match, stale-envelope exactness of the returned
min_d2, and the telemetry counters. The hypothesis-randomized variants live
in test_kmeanspp_properties.py (skipped when hypothesis is absent); these
run always."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import telemetry
from repro.core.engine import _REJECT_ATTEMPTS, ClusterEngine


def _pts(n=512, d=4, seed=1):
    return jax.random.normal(jax.random.key(seed), (n, d), jnp.float32)


# ---------------------------------------------------------------------------
# shared-uniform-stream bitwise pin: refresh_block=1 == sampler='tiled'
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
@pytest.mark.parametrize("seed", [0, 7])
def test_rejection_fresh_envelope_pins_tiled(backend, seed):
    """With refresh_block=1 every round's envelope is fresh, p == q bitwise,
    the first proposal always accepts through the SAME uniform derivation
    categorical_tiled uses — so the chosen indices are bitwise identical."""
    pts = _pts(seed=seed + 1)
    key = jax.random.key(seed)
    eng = ClusterEngine(backend)
    t = eng.seed(key, pts, 9, sampler="tiled")
    r = eng.seed(key, pts, 9, sampler="rejection", refresh_block=1)
    np.testing.assert_array_equal(np.asarray(t.indices), np.asarray(r.indices))
    assert np.asarray(r.accepts)[1:].all()
    assert (np.asarray(r.proposals)[1:] == 1).all()


def test_rejection_weighted_pin_and_validity():
    """The weighted path (k-means|| reduce) keeps both the pin and the
    envelope-domination argument (q_i = stale_min_d2_i * w_i >= p_i)."""
    pts = _pts(n=256, seed=3)
    w = jax.random.uniform(jax.random.key(4), (256,)) + 0.1
    key = jax.random.key(5)
    eng = ClusterEngine("fused")
    t = eng.seed(key, pts, 6, weights=w, sampler="tiled")
    r1 = eng.seed(key, pts, 6, weights=w, sampler="rejection",
                  refresh_block=1)
    np.testing.assert_array_equal(np.asarray(t.indices),
                                  np.asarray(r1.indices))
    r4 = eng.seed(key, pts, 6, weights=w, sampler="rejection",
                  refresh_block=4)
    idx = np.asarray(r4.indices)
    assert ((0 <= idx) & (idx < 256)).all() and len(set(idx.tolist())) == 6


def test_rejection_batched_pins_tiled_per_problem():
    """The vmapped (batched) path keeps the pin, problem by problem."""
    B = 4
    pts = jax.random.normal(jax.random.key(3), (B, 128, 3), jnp.float32)
    keys = jax.random.split(jax.random.key(4), B)
    eng = ClusterEngine("fused")
    t = eng.seed_batched(keys, pts, 5, sampler="tiled")
    r = eng.seed_batched(keys, pts, 5, sampler="rejection", refresh_block=1)
    np.testing.assert_array_equal(np.asarray(t.indices), np.asarray(r.indices))
    for b in range(B):
        single = eng.seed(keys[b], pts[b], 5, sampler="rejection",
                          refresh_block=1)
        np.testing.assert_array_equal(np.asarray(r.indices[b]),
                                      np.asarray(single.indices))


# ---------------------------------------------------------------------------
# stale envelopes (refresh_block > 1): exactness + telemetry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("refresh_block", [2, 8])
def test_rejection_returns_exact_min_d2(refresh_block):
    """Rounds skip the full refresh, but the loop settles its refresh debt
    before returning: min_d2 is exact over all k chosen seeds."""
    pts = _pts(n=1024, seed=6)
    res = ClusterEngine("fused").seed(jax.random.key(7), pts, 12,
                                      sampler="rejection",
                                      refresh_block=refresh_block)
    d2 = jnp.min(jnp.sum((pts[:, None, :] - res.centroids[None]) ** 2, -1), 1)
    np.testing.assert_allclose(np.asarray(res.min_d2), np.asarray(d2),
                               rtol=2e-4, atol=1e-4)
    idx = np.asarray(res.indices)
    assert len(set(idx.tolist())) == 12
    telemetry.check_rejection_counters(res.proposals, res.accepts, 12,
                                       max_attempts=_REJECT_ATTEMPTS)


def test_rejection_skips_full_refresh_between_blocks():
    """The whole point: with refresh_block=P only ~k/P rounds touch the full
    dataset. Non-refresh rounds report skipped == all tiles (they read zero
    tiles) under bound gating."""
    pts = _pts(n=4096, d=8, seed=8)
    res = ClusterEngine("fused").seed(jax.random.key(9), pts, 16,
                                      sampler="rejection", refresh_block=8)
    skips = np.asarray(res.skipped)
    accs = np.asarray(res.accepts)
    # rounds that accepted without a refresh never ran the round kernel; the
    # fused backend's seed_round runs ONE fused pass (skipped reports the
    # gating outcome), so "never ran" rounds show the all-tiles sentinel
    n_tiles_sentinel = skips.max()
    assert (skips == n_tiles_sentinel).sum() >= 16 - (16 // 8 + 2), skips
    assert accs[1:].sum() >= 12  # stale envelopes still mostly accept


def test_rejection_duplicate_points_terminates():
    """All-identical points: after the first seed every D^2 is 0, every
    proposal rejects (p = q = 0 fails the strict test), and the exact-
    fallback draw's uniform guard must still terminate with valid indices."""
    pts = jnp.ones((64, 3), jnp.float32) * 2.5
    res = ClusterEngine("fused").seed(jax.random.key(10), pts, 5,
                                      sampler="rejection", refresh_block=4)
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < 64)).all()
    assert np.asarray(res.min_d2).max() < 1e-6
    # rejected-through rounds exhaust the truncation depth, then fall back
    assert (np.asarray(res.proposals)[1:] == _REJECT_ATTEMPTS).all()
    assert (np.asarray(res.accepts)[1:] == 0).all()


# ---------------------------------------------------------------------------
# marginal distribution: two-sample chi-square vs sampler='tiled'
# ---------------------------------------------------------------------------


def test_rejection_matches_tiled_seed_distribution_chi_square():
    """Beyond the shared-key pin: the MARGINAL index distribution of the
    second seed under stale envelopes (refresh_block=4) matches
    sampler='tiled' across B independent deterministic keys. Hand-rolled
    two-sample chi-square (no scipy): both samplers are exact, so
    sum (c1-c2)^2/(c1+c2) ~ chi2(#buckets - 1)."""
    n, d, k, B = 64, 2, 3, 400
    pts = jax.random.normal(jax.random.key(11), (n, d), jnp.float32)
    batch = jnp.broadcast_to(pts, (B, n, d))
    keys = jax.random.split(jax.random.key(12), B)
    eng = ClusterEngine("fused")
    t = np.asarray(eng.seed_batched(keys, batch, k, sampler="tiled").indices)
    r = np.asarray(eng.seed_batched(keys, batch, k, sampler="rejection",
                                    refresh_block=4).indices)
    bins = 16
    c_t = np.bincount(t[:, 1] // (n // bins), minlength=bins).astype(float)
    c_r = np.bincount(r[:, 1] // (n // bins), minlength=bins).astype(float)
    tot = c_t + c_r
    stat = float(np.sum(np.where(tot > 0,
                                 (c_t - c_r) ** 2 / np.maximum(tot, 1.0),
                                 0.0)))
    # df = 15; P(chi2 > 60) ~ 2e-7 — a biased fallback or a broken envelope
    # blows two orders of magnitude past this, fp wiggle cannot reach it
    assert stat < 60.0, (stat, c_t, c_r)


# ---------------------------------------------------------------------------
# coarse-to-fine proposal (ISSUE 9): pins, counters, max_attempts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
def test_hier_and_flat_pin_tiled_at_refresh_block_1(backend):
    """proposal='hier' at refresh_block=1 is bitwise sampler='tiled' AND
    bitwise proposal='flat': no pending centroids at proposal time means
    every per-tile cap is +inf and the coarse draw telescopes to the flat
    one through the identical uniform."""
    pts = _pts(n=256, seed=13)
    key = jax.random.key(14)
    eng = ClusterEngine(backend)
    t = eng.seed(key, pts, 7, sampler="tiled")
    h = eng.seed(key, pts, 7, sampler="rejection", refresh_block=1,
                 proposal="hier")
    f = eng.seed(key, pts, 7, sampler="rejection", refresh_block=1,
                 proposal="flat")
    np.testing.assert_array_equal(np.asarray(t.indices), np.asarray(h.indices))
    np.testing.assert_array_equal(np.asarray(t.indices), np.asarray(f.indices))


def test_hier_weighted_pin_and_validity():
    """Weighted coarse draw (the Capó per-super coreset weights) keeps the
    refresh_block=1 pin and draws valid, distinct seeds on stale envelopes."""
    pts = _pts(n=256, seed=15)
    w = jax.random.uniform(jax.random.key(16), (256,)) + 0.1
    key = jax.random.key(17)
    eng = ClusterEngine("fused")
    t = eng.seed(key, pts, 6, weights=w, sampler="tiled")
    h1 = eng.seed(key, pts, 6, weights=w, sampler="rejection",
                  refresh_block=1, proposal="hier")
    np.testing.assert_array_equal(np.asarray(t.indices),
                                  np.asarray(h1.indices))
    h8 = eng.seed(key, pts, 6, weights=w, sampler="rejection",
                  refresh_block=8, proposal="hier")
    idx = np.asarray(h8.indices)
    assert ((0 <= idx) & (idx < 256)).all() and len(set(idx.tolist())) == 6


def test_hier_batched_pins_tiled_per_problem():
    B = 4
    pts = jax.random.normal(jax.random.key(18), (B, 128, 3), jnp.float32)
    keys = jax.random.split(jax.random.key(19), B)
    eng = ClusterEngine("fused")
    t = eng.seed_batched(keys, pts, 5, sampler="tiled")
    h = eng.seed_batched(keys, pts, 5, sampler="rejection", refresh_block=1,
                         proposal="hier")
    np.testing.assert_array_equal(np.asarray(t.indices), np.asarray(h.indices))


def test_hier_counters_and_flat_counters():
    """proposal='hier' rounds visit one super window per attempt (+1 on the
    exact fallback) and may tighten tiles once centroids are pending;
    proposal='flat' reports both counters identically zero."""
    pts = _pts(n=2048, d=4, seed=20)
    eng = ClusterEngine("fused")
    h = eng.seed(jax.random.key(21), pts, 16, sampler="rejection",
                 refresh_block=8, proposal="hier")
    telemetry.check_rejection_counters(h.proposals, h.accepts, 16,
                                       max_attempts=_REJECT_ATTEMPTS)
    telemetry.check_hier_counters(h.tightened, h.supers, h.proposals, 16,
                                  hier=True)
    f = eng.seed(jax.random.key(21), pts, 16, sampler="rejection",
                 refresh_block=8, proposal="flat")
    telemetry.check_hier_counters(f.tightened, f.supers, f.proposals, 16,
                                  hier=False)


@pytest.mark.parametrize("backend,B,bins,lim",
                         [("reference", 200, 8, 40.0),
                          ("fused", 400, 16, 60.0),
                          ("pallas", 150, 8, 40.0)])
def test_hier_rb8_matches_tiled_distribution_chi_square(backend, B, bins,
                                                        lim):
    """Marginal exactness of the coarse-to-fine draw ON A STALE, TIGHTENED
    envelope: the 3rd seed (two centroids pending — caps active) under
    proposal='hier', refresh_block=8 matches sampler='tiled' across B
    independent keys (two-sample chi-square, both samplers exact)."""
    n, d, k = 64, 2, 4
    pts = jax.random.normal(jax.random.key(22), (n, d), jnp.float32)
    batch = jnp.broadcast_to(pts, (B, n, d))
    keys = jax.random.split(jax.random.key(23), B)
    eng = ClusterEngine(backend)
    t = np.asarray(eng.seed_batched(keys, batch, k, sampler="tiled").indices)
    h = np.asarray(eng.seed_batched(keys, batch, k, sampler="rejection",
                                    refresh_block=8,
                                    proposal="hier").indices)
    c_t = np.bincount(t[:, 2] // (n // bins), minlength=bins).astype(float)
    c_h = np.bincount(h[:, 2] // (n // bins), minlength=bins).astype(float)
    tot = c_t + c_h
    stat = float(np.sum(np.where(tot > 0,
                                 (c_t - c_h) ** 2 / np.maximum(tot, 1.0),
                                 0.0)))
    assert stat < lim, (stat, c_t, c_h)


def test_hier_rb8_matches_flat_distribution_chi_square():
    """hier vs flat at the SAME refresh_block: two exact samplers over the
    same target, different proposal shapes — marginals must agree."""
    n, d, k, B, bins = 64, 2, 4, 400, 16
    pts = jax.random.normal(jax.random.key(24), (n, d), jnp.float32)
    batch = jnp.broadcast_to(pts, (B, n, d))
    keys = jax.random.split(jax.random.key(25), B)
    eng = ClusterEngine("fused")
    f = np.asarray(eng.seed_batched(keys, batch, k, sampler="rejection",
                                    refresh_block=8,
                                    proposal="flat").indices)
    h = np.asarray(eng.seed_batched(keys, batch, k, sampler="rejection",
                                    refresh_block=8,
                                    proposal="hier").indices)
    c_f = np.bincount(f[:, 2] // (n // bins), minlength=bins).astype(float)
    c_h = np.bincount(h[:, 2] // (n // bins), minlength=bins).astype(float)
    tot = c_f + c_h
    stat = float(np.sum(np.where(tot > 0,
                                 (c_f - c_h) ** 2 / np.maximum(tot, 1.0),
                                 0.0)))
    assert stat < 60.0, (stat, c_f, c_h)


def test_max_attempts_parameter_truncates_and_reports():
    """Satellite: the 8-attempt truncation is now a parameter. Duplicate
    points reject every proposal, so every later round must report exactly
    max_attempts proposals before the exact fallback — for non-default
    depths too — and the telemetry invariant chain follows the parameter."""
    pts = jnp.ones((64, 3), jnp.float32) * 2.5
    eng = ClusterEngine("fused")
    for ma in (3, 8):
        res = eng.seed(jax.random.key(26), pts, 5, sampler="rejection",
                       refresh_block=4, max_attempts=ma)
        assert (np.asarray(res.proposals)[1:] == ma).all()
        assert (np.asarray(res.accepts)[1:] == 0).all()
        telemetry.check_rejection_counters(res.proposals, res.accepts, 5,
                                           max_attempts=ma)
        telemetry.check_hier_counters(res.tightened, res.supers,
                                      res.proposals, 5, hier=True)
        idx = np.asarray(res.indices)
        assert ((0 <= idx) & (idx < 64)).all()


def test_max_attempts_does_not_change_healthy_draws():
    """On well-separated data a raised/lowered depth only matters for rounds
    that WOULD exhaust it; with refresh_block=1 every round accepts at
    attempt 1, so any max_attempts >= 1 is bitwise identical."""
    pts = _pts(n=256, seed=27)
    key = jax.random.key(28)
    eng = ClusterEngine("fused")
    a = eng.seed(key, pts, 7, sampler="rejection", refresh_block=1,
                 max_attempts=1)
    b = eng.seed(key, pts, 7, sampler="rejection", refresh_block=1,
                 max_attempts=8)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
