"""Deterministic acceptance tests for sampler='rejection' (ISSUE 6): the
shared-uniform-stream bitwise pin against sampler='tiled', the two-sample
chi-square distribution match, stale-envelope exactness of the returned
min_d2, and the telemetry counters. The hypothesis-randomized variants live
in test_kmeanspp_properties.py (skipped when hypothesis is absent); these
run always."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import telemetry
from repro.core.engine import _REJECT_ATTEMPTS, ClusterEngine


def _pts(n=512, d=4, seed=1):
    return jax.random.normal(jax.random.key(seed), (n, d), jnp.float32)


# ---------------------------------------------------------------------------
# shared-uniform-stream bitwise pin: refresh_block=1 == sampler='tiled'
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
@pytest.mark.parametrize("seed", [0, 7])
def test_rejection_fresh_envelope_pins_tiled(backend, seed):
    """With refresh_block=1 every round's envelope is fresh, p == q bitwise,
    the first proposal always accepts through the SAME uniform derivation
    categorical_tiled uses — so the chosen indices are bitwise identical."""
    pts = _pts(seed=seed + 1)
    key = jax.random.key(seed)
    eng = ClusterEngine(backend)
    t = eng.seed(key, pts, 9, sampler="tiled")
    r = eng.seed(key, pts, 9, sampler="rejection", refresh_block=1)
    np.testing.assert_array_equal(np.asarray(t.indices), np.asarray(r.indices))
    assert np.asarray(r.accepts)[1:].all()
    assert (np.asarray(r.proposals)[1:] == 1).all()


def test_rejection_weighted_pin_and_validity():
    """The weighted path (k-means|| reduce) keeps both the pin and the
    envelope-domination argument (q_i = stale_min_d2_i * w_i >= p_i)."""
    pts = _pts(n=256, seed=3)
    w = jax.random.uniform(jax.random.key(4), (256,)) + 0.1
    key = jax.random.key(5)
    eng = ClusterEngine("fused")
    t = eng.seed(key, pts, 6, weights=w, sampler="tiled")
    r1 = eng.seed(key, pts, 6, weights=w, sampler="rejection",
                  refresh_block=1)
    np.testing.assert_array_equal(np.asarray(t.indices),
                                  np.asarray(r1.indices))
    r4 = eng.seed(key, pts, 6, weights=w, sampler="rejection",
                  refresh_block=4)
    idx = np.asarray(r4.indices)
    assert ((0 <= idx) & (idx < 256)).all() and len(set(idx.tolist())) == 6


def test_rejection_batched_pins_tiled_per_problem():
    """The vmapped (batched) path keeps the pin, problem by problem."""
    B = 4
    pts = jax.random.normal(jax.random.key(3), (B, 128, 3), jnp.float32)
    keys = jax.random.split(jax.random.key(4), B)
    eng = ClusterEngine("fused")
    t = eng.seed_batched(keys, pts, 5, sampler="tiled")
    r = eng.seed_batched(keys, pts, 5, sampler="rejection", refresh_block=1)
    np.testing.assert_array_equal(np.asarray(t.indices), np.asarray(r.indices))
    for b in range(B):
        single = eng.seed(keys[b], pts[b], 5, sampler="rejection",
                          refresh_block=1)
        np.testing.assert_array_equal(np.asarray(r.indices[b]),
                                      np.asarray(single.indices))


# ---------------------------------------------------------------------------
# stale envelopes (refresh_block > 1): exactness + telemetry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("refresh_block", [2, 8])
def test_rejection_returns_exact_min_d2(refresh_block):
    """Rounds skip the full refresh, but the loop settles its refresh debt
    before returning: min_d2 is exact over all k chosen seeds."""
    pts = _pts(n=1024, seed=6)
    res = ClusterEngine("fused").seed(jax.random.key(7), pts, 12,
                                      sampler="rejection",
                                      refresh_block=refresh_block)
    d2 = jnp.min(jnp.sum((pts[:, None, :] - res.centroids[None]) ** 2, -1), 1)
    np.testing.assert_allclose(np.asarray(res.min_d2), np.asarray(d2),
                               rtol=2e-4, atol=1e-4)
    idx = np.asarray(res.indices)
    assert len(set(idx.tolist())) == 12
    telemetry.check_rejection_counters(res.proposals, res.accepts, 12,
                                       max_attempts=_REJECT_ATTEMPTS)


def test_rejection_skips_full_refresh_between_blocks():
    """The whole point: with refresh_block=P only ~k/P rounds touch the full
    dataset. Non-refresh rounds report skipped == all tiles (they read zero
    tiles) under bound gating."""
    pts = _pts(n=4096, d=8, seed=8)
    res = ClusterEngine("fused").seed(jax.random.key(9), pts, 16,
                                      sampler="rejection", refresh_block=8)
    skips = np.asarray(res.skipped)
    accs = np.asarray(res.accepts)
    # rounds that accepted without a refresh never ran the round kernel; the
    # fused backend's seed_round runs ONE fused pass (skipped reports the
    # gating outcome), so "never ran" rounds show the all-tiles sentinel
    n_tiles_sentinel = skips.max()
    assert (skips == n_tiles_sentinel).sum() >= 16 - (16 // 8 + 2), skips
    assert accs[1:].sum() >= 12  # stale envelopes still mostly accept


def test_rejection_duplicate_points_terminates():
    """All-identical points: after the first seed every D^2 is 0, every
    proposal rejects (p = q = 0 fails the strict test), and the exact-
    fallback draw's uniform guard must still terminate with valid indices."""
    pts = jnp.ones((64, 3), jnp.float32) * 2.5
    res = ClusterEngine("fused").seed(jax.random.key(10), pts, 5,
                                      sampler="rejection", refresh_block=4)
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < 64)).all()
    assert np.asarray(res.min_d2).max() < 1e-6
    # rejected-through rounds exhaust the truncation depth, then fall back
    assert (np.asarray(res.proposals)[1:] == _REJECT_ATTEMPTS).all()
    assert (np.asarray(res.accepts)[1:] == 0).all()


# ---------------------------------------------------------------------------
# marginal distribution: two-sample chi-square vs sampler='tiled'
# ---------------------------------------------------------------------------


def test_rejection_matches_tiled_seed_distribution_chi_square():
    """Beyond the shared-key pin: the MARGINAL index distribution of the
    second seed under stale envelopes (refresh_block=4) matches
    sampler='tiled' across B independent deterministic keys. Hand-rolled
    two-sample chi-square (no scipy): both samplers are exact, so
    sum (c1-c2)^2/(c1+c2) ~ chi2(#buckets - 1)."""
    n, d, k, B = 64, 2, 3, 400
    pts = jax.random.normal(jax.random.key(11), (n, d), jnp.float32)
    batch = jnp.broadcast_to(pts, (B, n, d))
    keys = jax.random.split(jax.random.key(12), B)
    eng = ClusterEngine("fused")
    t = np.asarray(eng.seed_batched(keys, batch, k, sampler="tiled").indices)
    r = np.asarray(eng.seed_batched(keys, batch, k, sampler="rejection",
                                    refresh_block=4).indices)
    bins = 16
    c_t = np.bincount(t[:, 1] // (n // bins), minlength=bins).astype(float)
    c_r = np.bincount(r[:, 1] // (n // bins), minlength=bins).astype(float)
    tot = c_t + c_r
    stat = float(np.sum(np.where(tot > 0,
                                 (c_t - c_r) ** 2 / np.maximum(tot, 1.0),
                                 0.0)))
    # df = 15; P(chi2 > 60) ~ 2e-7 — a biased fallback or a broken envelope
    # blows two orders of magnitude past this, fp wiggle cannot reach it
    assert stat < 60.0, (stat, c_t, c_r)
