"""Dry-run machinery on a small (8-device) mesh: jitted_cell compiles for
train/decode, the HLO analyzer sees the schedule, and the §Perf variants
(a2a dispatch, bf16 serving, sequence sharding) behave as designed."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

_WORKER = Path(__file__).parent / "dryrun_worker.py"


@pytest.fixture(scope="module")
def worker_out():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, str(_WORKER)], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, \
        f"worker failed\nstdout: {proc.stdout[-4000:]}\nstderr: {proc.stderr[-4000:]}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_train_cell_compiles_with_analysis(worker_out):
    assert worker_out["train_flops_positive"]
    assert worker_out["train_has_allreduce"]
    assert worker_out["mem_analysis_present"]
    assert worker_out["cost_analysis_present"]


def test_a2a_dispatch_in_schedule(worker_out):
    assert worker_out["a2a_in_schedule"]


def test_a2a_reduces_wire_bytes(worker_out):
    assert worker_out["a2a_less_wire"], \
        (worker_out["a2a_bytes"], worker_out["gather_bytes"])


def test_bf16_serving_halves_params(worker_out):
    assert worker_out["bf16_args_smaller"]


def test_seq_shard_compiles(worker_out):
    assert worker_out["sp_compiles"]
