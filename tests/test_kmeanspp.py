"""Core paper tests: serial == parallel seed selection, sampling correctness,
Lloyd monotonicity, k-means|| behaviour. The hypothesis property tests live in
test_kmeanspp_properties.py (skipped when hypothesis is absent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (kmeanspp, kmeans, lloyd, random_init,
                        kmeans_parallel_init, quality, sampling)
from repro.core.kmeanspp import pairwise_d2
from repro.core.lloyd import assign, update
from repro.data.synthetic import blobs


def _points(n=512, d=2, k=8, seed=0):
    pts, _ = blobs(n, d, k, seed=seed)
    return jnp.asarray(pts)


# ---------------------------------------------------------------------------
# paper claim: parallel variants pick THE SAME seeds as the serial baseline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["global", "fused"])
def test_parallel_matches_serial_exactly(variant):
    pts = _points()
    key = jax.random.PRNGKey(42)
    ref = kmeanspp(key, pts, 10, variant="serial", sampler="cdf")
    got = kmeanspp(key, pts, 10, variant=variant, sampler="cdf")
    np.testing.assert_array_equal(np.asarray(ref.indices),
                                  np.asarray(got.indices))
    np.testing.assert_allclose(np.asarray(ref.centroids),
                               np.asarray(got.centroids), rtol=1e-6)


@pytest.mark.parametrize("variant", ["pallas_constant", "pallas_fused"])
def test_pallas_variants_match_serial(variant):
    pts = _points(n=256)
    key = jax.random.PRNGKey(7)
    ref = kmeanspp(key, pts, 6, variant="serial", sampler="cdf")
    got = kmeanspp(key, pts, 6, variant=variant, sampler="cdf")
    np.testing.assert_array_equal(np.asarray(ref.indices),
                                  np.asarray(got.indices))


def test_seeds_are_data_points():
    pts = _points(n=300, d=5)
    res = kmeanspp(jax.random.PRNGKey(0), pts, 12)
    cents = np.asarray(res.centroids)
    P = np.asarray(pts)
    for i, idx in enumerate(np.asarray(res.indices)):
        np.testing.assert_allclose(cents[i], P[idx], rtol=1e-6)


def test_min_d2_is_final_potential():
    pts = _points()
    res = kmeanspp(jax.random.PRNGKey(1), pts, 8)
    expect = np.min(np.asarray(pairwise_d2(pts, res.centroids)), axis=1)
    np.testing.assert_allclose(np.asarray(res.min_d2), expect,
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# sampling ∝ D^2 (the k-means++ distribution itself)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["cdf", "gumbel"])
def test_categorical_samples_proportional(method):
    w = jnp.asarray([1.0, 0.0, 3.0, 6.0])
    keys = jax.random.split(jax.random.PRNGKey(3), 4000)
    idx = jax.vmap(lambda k: sampling.categorical(k, w, method=method))(keys)
    counts = np.bincount(np.asarray(idx), minlength=4)
    assert counts[1] == 0, "zero-weight index must never be sampled"
    freq = counts / counts.sum()
    expect = np.asarray(w) / float(jnp.sum(w))
    np.testing.assert_allclose(freq, expect, atol=0.03)


def test_gumbel_topk_without_replacement():
    w = jnp.arange(1.0, 33.0)
    idx = sampling.gumbel_topk(jax.random.PRNGKey(0), jnp.log(w), 8)
    assert len(set(np.asarray(idx).tolist())) == 8


# ---------------------------------------------------------------------------
# Lloyd clustering
# ---------------------------------------------------------------------------

def test_lloyd_potential_monotone():
    pts = _points(n=600, d=3, k=6)
    seeds = kmeanspp(jax.random.PRNGKey(0), pts, 6).centroids
    cents = seeds
    prev = np.inf
    for _ in range(8):
        a, m = assign(pts, cents)
        inertia = float(jnp.sum(m))
        assert inertia <= prev + 1e-4, "k-means potential must not increase"
        prev = inertia
        cents = update(pts, a, 6, prev_centroids=cents)


def test_kmeanspp_beats_random_init():
    pts = _points(n=2048, d=2, k=16, seed=3)
    kpp = rnd = 0.0
    for s in range(3):
        key = jax.random.PRNGKey(s)
        kpp += float(quality.inertia(pts, kmeanspp(key, pts, 16).centroids))
        rnd += float(quality.inertia(pts, random_init(key, pts, 16).centroids))
    assert kpp < rnd, (kpp, rnd)


def test_kmeans_end_to_end_quality():
    pts = _points(n=2048, d=2, k=8, seed=5)
    res = kmeans(jax.random.PRNGKey(0), pts, 8)
    # well-separated blobs with spread 0.05: inertia/point ~ d * spread^2
    assert float(res.inertia) / 2048 < 3 * 2 * 0.05 ** 2
    assert int(res.n_iters) <= 50


def test_empty_cluster_keeps_prev_centroid():
    pts = jnp.asarray([[0.0, 0.0], [1.0, 1.0], [1.1, 1.0]])
    cents = jnp.asarray([[0.0, 0.0], [1.0, 1.0], [99.0, 99.0]])
    a, _ = assign(pts, cents)
    new = update(pts, a, 3, prev_centroids=cents)
    np.testing.assert_allclose(np.asarray(new)[2], [99.0, 99.0])


# ---------------------------------------------------------------------------
# k-means|| (Bahmani) baseline
# ---------------------------------------------------------------------------

def test_kmeans_parallel_init_valid():
    pts = _points(n=1024, d=2, k=8)
    res = kmeans_parallel_init(jax.random.PRNGKey(0), pts, 8, rounds=4)
    assert res.centroids.shape == (8, 2)
    P = np.asarray(pts)
    for i, idx in enumerate(np.asarray(res.indices)):
        np.testing.assert_allclose(np.asarray(res.centroids)[i], P[idx],
                                   rtol=1e-5)


def test_kmeans_parallel_quality_close_to_kmeanspp():
    pts = _points(n=4096, d=2, k=16, seed=9)
    key = jax.random.PRNGKey(0)
    phi_pp = float(quality.inertia(pts, kmeanspp(key, pts, 16).centroids))
    phi_par = float(quality.inertia(
        pts, kmeans_parallel_init(key, pts, 16).centroids))
    assert phi_par < 5 * phi_pp, (phi_par, phi_pp)
