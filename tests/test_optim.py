"""Optimizer + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.grad_compress import (CompressConfig, compress_with_ef,
                                       init_ef, roundtrip, wire_bytes)


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                            decay_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    target = jnp.asarray([1.0, 2.0])
    state = adamw.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.1)


def test_grad_clip_and_schedule():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(gn) > 100
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, decay_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 1000]]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)   # floor
    assert lrs[3] < lrs[2]


def test_no_decay_on_vectors():
    cfg = adamw.AdamWConfig(lr=0.0, weight_decay=1.0, grad_clip=0)
    # lr=0: params must not move regardless of decay
    params = {"norm": jnp.ones((4,)), "w": jnp.ones((4, 4))}
    state = adamw.init(params)
    g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw.apply(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(new["norm"]), 1.0)


@pytest.mark.parametrize("codec", ["int8", "kmeans"])
def test_roundtrip_error_bounded(codec):
    cfg = CompressConfig(codec=codec, kmeans_bits=4, kmeans_iters=4)
    g = jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 0.01
    q = roundtrip(cfg, g, jax.random.PRNGKey(1))
    rel = float(jnp.linalg.norm(q - g) / jnp.linalg.norm(g))
    assert rel < 0.25, rel


def test_kmeans_codec_beats_uniform_at_same_bits():
    """Heavy-tailed gradients: a 4-bit k-means codebook should beat 4-bit
    UNIFORM quantization clearly (the reason to use the paper's algorithm)."""
    key = jax.random.PRNGKey(2)
    g = jax.random.t(key, df=3.0, shape=(8192,)) * 0.01   # heavy tails

    cfg_km = CompressConfig(codec="kmeans", kmeans_bits=4, kmeans_iters=8)
    q_km = roundtrip(cfg_km, g, jax.random.PRNGKey(3))
    # 4-bit uniform: 16 levels over [-max, max]
    scale = jnp.max(jnp.abs(g)) / 7.5
    q_un = jnp.clip(jnp.round(g / scale), -8, 7) * scale
    err_km = float(jnp.mean((q_km - g) ** 2))
    err_un = float(jnp.mean((q_un - g) ** 2))
    assert err_km < err_un, (err_km, err_un)


def test_error_feedback_unbiased():
    """With EF, the *accumulated* compressed signal tracks the accumulated
    true gradient (compression error does not build up as bias). Entries far
    below the int8 step (1/127 of max) emit zeros most steps and a full
    quantum occasionally — the MEAN converges at rate O(quantum/steps)."""
    cfg = CompressConfig(codec="int8")
    g = {"w": jnp.asarray([2e-3, -4e-3, 6e-3, 1.0])}  # small + huge entries
    ef = init_ef(g)
    total = jnp.zeros((4,))
    steps = 200
    for s in range(steps):
        comp, ef = compress_with_ef(cfg, g, ef, jax.random.PRNGKey(s))
        total = total + comp["w"]
    mean = np.asarray(total) / steps
    quantum = 1.0 / 127
    np.testing.assert_allclose(mean, np.asarray(g["w"]),
                               atol=2 * quantum / steps, rtol=0.01)


def test_wire_bytes():
    g = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    assert wire_bytes(CompressConfig("none"), g) == 4096
    assert wire_bytes(CompressConfig("int8"), g) == 1024
    assert wire_bytes(CompressConfig("kmeans", kmeans_bits=4), g) == 512
