"""Worker for test_dryrun_small.py: exercises the jitted_cell + analyzer
machinery on an 8-device mesh with SMOKE configs (subprocess — device count
is locked at jax init)."""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import dataclasses
import json
import sys

import jax

from repro.configs.common import ShapeConfig
from repro.configs.registry import get_config
from repro.launch.step import jitted_cell
from repro.models.sharding import use_mesh
from repro.roofline.hlo import analyze

out = {}
mesh = jax.make_mesh((4, 2), ("data", "model"))
TINY_TRAIN = ShapeConfig("t", 128, 8, "train")
TINY_DECODE = ShapeConfig("d", 256, 8, "decode")


def compile_cell(cfg, shape):
    with use_mesh(mesh):
        jf, args = jitted_cell(cfg, shape, mesh)
        return jf.lower(*args).compile()


# 1. dense train cell: compiles, analyzer sees flops + collectives,
#    scan trip count (2 layers) is applied
cfg = get_config("deepseek-7b", smoke=True)
compiled = compile_cell(cfg, TINY_TRAIN)
r = analyze(compiled.as_text())
out["train_flops_positive"] = r["flops"] > 1e6
out["train_has_allreduce"] = r["collectives"]["by_kind"].get("all-reduce", 0) > 0
out["mem_analysis_present"] = compiled.memory_analysis() is not None
from repro.compat import cost_analysis
out["cost_analysis_present"] = "flops" in cost_analysis(compiled)

# 2. MoE a2a variant compiles and has all-to-all in the schedule
cfg_moe = dataclasses.replace(get_config("qwen2-moe-a2.7b", smoke=True),
                              moe_dispatch="a2a", moe_chunk=0)
compiled2 = compile_cell(cfg_moe, TINY_TRAIN)
r2 = analyze(compiled2.as_text())
out["a2a_in_schedule"] = r2["collectives"]["by_kind"].get("all-to-all", 0) > 0

# 3. gather baseline moves MORE collective bytes than a2a (the hillclimb)
cfg_g = dataclasses.replace(get_config("qwen2-moe-a2.7b", smoke=True),
                            moe_dispatch="gather")
r3 = analyze(compile_cell(cfg_g, TINY_TRAIN).as_text())
out["a2a_less_wire"] = (r2["collectives"]["total_bytes"]
                        < r3["collectives"]["total_bytes"])
out["a2a_bytes"] = r2["collectives"]["total_bytes"]
out["gather_bytes"] = r3["collectives"]["total_bytes"]

# 4. decode cell with bf16 serving params: argument bytes halve vs fp32
cfg_d = get_config("deepseek-7b", smoke=True)
m_f32 = compile_cell(cfg_d, TINY_DECODE).memory_analysis()
cfg_bf = dataclasses.replace(cfg_d, serve_dtype="bfloat16")
m_bf16 = compile_cell(cfg_bf, TINY_DECODE).memory_analysis()
out["bf16_args_smaller"] = (m_bf16.argument_size_in_bytes
                            < m_f32.argument_size_in_bytes)

# 5. seq_shard variant compiles
cfg_sp = dataclasses.replace(get_config("deepseek-7b", smoke=True),
                             seq_shard=True)
compile_cell(cfg_sp, TINY_TRAIN)
out["sp_compiles"] = True

print(json.dumps(out))
sys.exit(0 if all(v for k, v in out.items() if isinstance(v, bool)) else 1)
