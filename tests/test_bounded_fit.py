"""Bounded Lloyd engine tests (ISSUE 4 tentpole): bitwise gated-vs-ungated
fit parity across backends (single, batch-grid, vmap), movement-bound skip
telemetry, spatial-ordering plumbing, kernel-level tiled/gated parity, and
the bf16 mini-batch path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bounds, quality
from repro.core.engine import ClusterEngine, FusedBackend, MeshBackend
from repro.data import ordering
from repro.data.synthetic import blobs
from repro.kernels import ops, ref


def _coherent(n=16384, d=2, k=4, seed=0, spread=0.05):
    pts, labels = blobs(n, d, k, seed=seed, spread=spread)
    order = np.argsort(labels, kind="stable")
    return jnp.asarray(pts[order])


# ---------------------------------------------------------------------------
# acceptance: fp32 bounded Lloyd is bitwise identical to the ungated path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
def test_bounded_fit_is_bitwise_exact(backend):
    """The gated fit must produce BITWISE the ungated fit's centroids,
    assignment, inertia and iteration count — while actually skipping."""
    pts = _coherent()
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(0), pts,
                                        4).centroids
    on = ClusterEngine(backend).fit(pts, seeds, max_iters=10, tol=-1.0)
    off = ClusterEngine(backend, bounds=False).fit(pts, seeds, max_iters=10,
                                                   tol=-1.0)
    np.testing.assert_array_equal(np.asarray(on.centroids),
                                  np.asarray(off.centroids))
    np.testing.assert_array_equal(np.asarray(on.assignment),
                                  np.asarray(off.assignment))
    assert float(on.inertia) == float(off.inertia)
    assert int(on.n_iters) == int(off.n_iters)
    assert off.skipped is None
    assert on.skipped is not None and on.skipped.shape == (10,)
    assert int(jnp.sum(on.skipped)) > 0, np.asarray(on.skipped)


@pytest.mark.parametrize("offset", [100.0, -3000.0])
def test_bounded_fit_exact_far_from_origin(offset):
    """The gap margin is ABSOLUTE in the operand magnitude (matmul-form fp32
    cancellation grows with ||x||^2): off-origin data is where a
    relative-only slack would silently break the bitwise claim."""
    pts = _coherent(seed=3) + offset
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(4), pts,
                                        4).centroids
    for backend in ("fused", "pallas"):
        on = ClusterEngine(backend).fit(pts, seeds, max_iters=8, tol=-1.0)
        off = ClusterEngine(backend, bounds=False).fit(pts, seeds,
                                                       max_iters=8, tol=-1.0)
        np.testing.assert_array_equal(np.asarray(on.centroids),
                                      np.asarray(off.centroids))
        np.testing.assert_array_equal(np.asarray(on.assignment),
                                      np.asarray(off.assignment))
        assert float(on.inertia) == float(off.inertia)


def test_bounded_fit_exact_on_shuffled_rows():
    """Shuffled rows give the gate nothing to prune — results must stay
    exactly the ungated fit's (exactness is layout-independent)."""
    pts = jnp.asarray(blobs(8192, 2, 4, seed=5)[0])
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(6), pts,
                                        4).centroids
    on = ClusterEngine("fused").fit(pts, seeds, max_iters=8)
    off = ClusterEngine("fused", bounds=False).fit(pts, seeds, max_iters=8)
    np.testing.assert_array_equal(np.asarray(on.centroids),
                                  np.asarray(off.centroids))
    assert float(on.inertia) == float(off.inertia)


def test_bounded_fit_skip_counts_agree_fused_vs_pallas():
    """The pure-JAX gate model and the compacted gated kernel must make the
    same skip decisions iteration by iteration."""
    pts = _coherent(seed=7)
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(8), pts,
                                        4).centroids
    f = ClusterEngine("fused").fit(pts, seeds, max_iters=10, tol=-1.0)
    p = ClusterEngine("pallas").fit(pts, seeds, max_iters=10, tol=-1.0)
    np.testing.assert_allclose(np.asarray(f.skipped), np.asarray(p.skipped),
                               atol=1)
    assert int(jnp.sum(f.skipped)) > 0


def test_bounded_fit_skip_rate_on_label_sorted_blobs():
    """Acceptance trajectory: well-separated label-sorted blobs reach a
    >= 50% assignment-tile skip rate by iteration 3 (0-indexed)."""
    n, d, k = 2 ** 16, 8, 16
    pts = _coherent(n=n, d=d, k=k, seed=0)
    eng = ClusterEngine("fused")
    seeds = eng.seed(jax.random.PRNGKey(1), pts, k).centroids
    res = eng.fit(pts, seeds, max_iters=6, tol=-1.0)
    n_tiles = -(-n // eng.backend.seed_tile(n, d, k))
    rate = np.asarray(res.skipped, np.float64) / n_tiles
    assert rate[3] >= 0.5, rate
    # skipping must not have changed the result
    off = ClusterEngine("fused", bounds=False).fit(pts, seeds, max_iters=6,
                                                   tol=-1.0)
    assert float(res.inertia) == float(off.inertia)


def test_bounded_fit_with_reseed_policy_stays_exact():
    """empty='reseed' moves centroids discontinuously — the movement bound
    must force recomputation (reseeded centroids have delta > 0) and keep
    gated == ungated bitwise."""
    pts = _coherent(seed=9)
    cents = jnp.concatenate([pts[:3], jnp.full((1, 2), 99.0)])
    on = ClusterEngine("fused").fit(pts, cents, max_iters=8, empty="reseed")
    off = ClusterEngine("fused", bounds=False).fit(pts, cents, max_iters=8,
                                                   empty="reseed")
    np.testing.assert_array_equal(np.asarray(on.centroids),
                                  np.asarray(off.centroids))
    assert float(on.inertia) == float(off.inertia)


# ---------------------------------------------------------------------------
# batch-grid / vmap / mesh composition
# ---------------------------------------------------------------------------


def test_bounded_fit_batched_matches_per_problem():
    """fit_batched (gated, batch-grid kernels under vmap) is bitwise the
    per-problem gated fit, and per-problem skip counters come back (B, it)."""
    B = 3
    bpts = jnp.stack([_coherent(n=4096, seed=10 + s) for s in range(B)])
    binit = jnp.stack([bpts[s][:4] for s in range(B)])
    for backend in ("fused", "pallas"):
        bat = ClusterEngine(backend).fit_batched(bpts, binit, max_iters=6,
                                                 tol=-1.0)
        assert bat.skipped.shape == (B, 6)
        for b in range(B):
            single = ClusterEngine(backend).fit(bpts[b], binit[b],
                                                max_iters=6, tol=-1.0)
            np.testing.assert_array_equal(np.asarray(bat.centroids[b]),
                                          np.asarray(single.centroids))
            np.testing.assert_array_equal(np.asarray(bat.assignment[b]),
                                          np.asarray(single.assignment))
            np.testing.assert_array_equal(np.asarray(bat.skipped[b]),
                                          np.asarray(single.skipped))


def test_bounded_fit_batched_gated_vs_ungated():
    B = 2
    bpts = jnp.stack([_coherent(n=4096, seed=20 + s) for s in range(B)])
    binit = jnp.stack([bpts[s][:4] for s in range(B)])
    on = ClusterEngine("pallas").fit_batched(bpts, binit, max_iters=6)
    off = ClusterEngine("pallas", bounds=False).fit_batched(bpts, binit,
                                                            max_iters=6)
    np.testing.assert_array_equal(np.asarray(on.centroids),
                                  np.asarray(off.centroids))
    np.testing.assert_array_equal(np.asarray(on.assignment),
                                  np.asarray(off.assignment))


def test_mesh_fit_composes_skip_counters():
    """The mesh fit psums the per-shard skipped-tile counts and matches the
    local fit's quality (1-device mesh: bitwise the local backend)."""
    mesh = jax.make_mesh((1,), ("data",))
    pts = _coherent(n=8192, seed=11)
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(2), pts,
                                        4).centroids
    res = ClusterEngine(MeshBackend(mesh=mesh, axes=("data",))).fit(
        pts, seeds, max_iters=8, tol=-1.0)
    local = ClusterEngine("fused").fit(pts, seeds, max_iters=8, tol=-1.0)
    assert res.skipped is not None and res.skipped.shape == (8,)
    np.testing.assert_array_equal(np.asarray(res.skipped),
                                  np.asarray(local.skipped))
    np.testing.assert_array_equal(np.asarray(res.centroids),
                                  np.asarray(local.centroids))


# ---------------------------------------------------------------------------
# result reporting (KmeansppResult-style audit surface for fit)
# ---------------------------------------------------------------------------


def test_fit_result_reports_skips_and_reorder_provenance():
    pts = _coherent(n=8192, seed=12)
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(3), pts,
                                        4).centroids
    res = ClusterEngine("fused").fit(pts, seeds, max_iters=20)
    # counters beyond the converged iteration stay zero (the shared contract
    # in repro.core.telemetry, pinned by tests/test_telemetry_contract.py)
    from repro.core import telemetry
    it = int(res.n_iters)
    assert it < 20
    telemetry.check_converged_zeros(res.skipped, it, 20, "skipped")
    assert res.reorder is None          # natural order: no provenance
    ordered = ClusterEngine("fused").fit(pts, seeds, max_iters=20,
                                         order="morton")
    assert ordered.reorder is not None and ordered.reorder.shape == (8192,)
    # the recorded permutation IS a permutation
    assert np.array_equal(np.sort(np.asarray(ordered.reorder)),
                          np.arange(8192))


def test_weighted_fit_keeps_legacy_contract():
    """Weighted fits take the legacy accumulated path: no skip telemetry,
    same numbers as before."""
    pts = _coherent(n=2048, seed=13)
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (2048,))) + 0.1
    res = ClusterEngine("fused").fit(pts, pts[:4], max_iters=6, weights=w)
    assert res.skipped is None


# ---------------------------------------------------------------------------
# spatial ordering: repro.data.ordering + engine plumbing
# ---------------------------------------------------------------------------


def test_morton_order_is_a_permutation_with_inverse():
    pts = jnp.asarray(blobs(1000, 3, 4, seed=1)[0])
    perm, inv = ordering.morton_order(pts)
    assert np.array_equal(np.sort(np.asarray(perm)), np.arange(1000))
    np.testing.assert_array_equal(np.asarray(perm[inv]), np.arange(1000))
    np.testing.assert_array_equal(np.asarray(inv[perm]), np.arange(1000))


def test_morton_order_improves_tile_coherence():
    """Z-ordering shuffled blobs must recover most of the skip rate the
    shuffled layout loses."""
    n, d, k = 2 ** 15, 8, 16
    pts = jnp.asarray(blobs(n, d, k, seed=2)[0])     # shuffled labels
    eng = ClusterEngine("fused")
    seeds = eng.seed(jax.random.PRNGKey(7), pts, k).centroids
    shuf = eng.fit(pts, seeds, max_iters=6, tol=-1.0)
    mort = eng.fit(pts, seeds, max_iters=6, tol=-1.0, order="morton")
    assert int(jnp.sum(mort.skipped)) > int(jnp.sum(shuf.skipped))
    assert int(jnp.sum(mort.skipped)) > 0


def test_morton_order_handles_one_dimension():
    """d=1 caps the per-dim bits at 16 (32//1 would overflow int32) and
    degenerates to a plain coordinate sort."""
    x = jax.random.uniform(jax.random.PRNGKey(0), (257, 1))
    perm, inv = ordering.morton_order(x)
    assert np.array_equal(np.sort(np.asarray(perm)), np.arange(257))
    sorted_x = np.asarray(x[perm, 0])
    assert (np.diff(sorted_x) >= -1e-4).all()   # 16-bit quantized sort
    np.testing.assert_array_equal(np.asarray(perm[inv]), np.arange(257))


def test_label_sort_order_groups_labels():
    labels = jnp.asarray([2, 0, 1, 0, 2, 1], jnp.int32)
    perm, inv = ordering.label_sort_order(labels)
    np.testing.assert_array_equal(np.asarray(labels[perm]),
                                  [0, 0, 1, 1, 2, 2])
    np.testing.assert_array_equal(np.asarray(perm[inv]), np.arange(6))


def test_spatial_order_dispatch_and_errors():
    pts = jnp.zeros((8, 2))
    with pytest.raises(ValueError, match="unknown ordering"):
        ordering.spatial_order(pts, method="hilbert")
    with pytest.raises(ValueError, match="labels"):
        ordering.spatial_order(pts, method="label")


def test_fit_order_returns_original_row_order():
    """order='morton' must hand results back in the CALLER's row order: the
    reported inertia must match an inertia recomputed from the returned
    (assignment, centroids) against the caller's points."""
    pts = jnp.asarray(blobs(4096, 2, 4, seed=3)[0])
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(9), pts,
                                        4).centroids
    res = ClusterEngine("fused").fit(pts, seeds, max_iters=10,
                                     order="morton")
    diff = pts - res.centroids[res.assignment]
    phi = float(jnp.sum(jnp.sum(diff * diff, axis=1)))
    np.testing.assert_allclose(phi, float(res.inertia), rtol=1e-4)
    # and the clustering quality matches the natural-order fit
    nat = ClusterEngine("fused").fit(pts, seeds, max_iters=10)
    np.testing.assert_allclose(float(res.inertia), float(nat.inertia),
                               rtol=1e-4)


def test_fit_order_accepts_precomputed_permutation():
    pts, labels = blobs(2 ** 15, 8, 8, seed=4)
    pts = jnp.asarray(pts)
    perm, _ = ordering.label_sort_order(jnp.asarray(labels))
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(10), pts,
                                        8).centroids
    res = ClusterEngine("fused").fit(pts, seeds, max_iters=8, tol=-1.0,
                                     order=perm)
    np.testing.assert_array_equal(np.asarray(res.reorder), np.asarray(perm))
    assert int(jnp.sum(res.skipped)) > 0   # label sort makes the gate fire


def test_kmeans_batched_order_matches_natural_quality():
    B, n, k = 2, 2048, 4
    bpts = jnp.stack([jnp.asarray(blobs(n, 2, k, seed=30 + s)[0])
                      for s in range(B)])
    key = jax.random.PRNGKey(11)
    nat = ClusterEngine("fused").kmeans_batched(key, bpts, k, max_iters=15)
    mort = ClusterEngine("fused").kmeans_batched(key, bpts, k, max_iters=15,
                                                 order="morton")
    assert mort.reorder.shape == (B, n)
    for b in range(B):
        diff = bpts[b] - mort.centroids[b][mort.assignment[b]]
        phi = float(jnp.sum(jnp.sum(diff * diff, axis=1)))
        np.testing.assert_allclose(phi, float(mort.inertia[b]), rtol=1e-4)
        assert phi < 3 * float(nat.inertia[b]) + 1e-6


# ---------------------------------------------------------------------------
# kernel-level parity (tiled vs oracle, gated vs tiled)
# ---------------------------------------------------------------------------


ASSIGN_TILED_SHAPES = [(1000, 5, 7, 128), (512, 2, 4, 128), (100, 3, 2, 128)]


@pytest.mark.parametrize("n,d,k,bn", ASSIGN_TILED_SHAPES)
def test_lloyd_assign_tiled_matches_ref(n, d, k, bn):
    pts = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    cents = jax.random.normal(jax.random.PRNGKey(1), (k, d))
    tps = bounds.tiles_per_super(-(-n // bn))
    got = ops.lloyd_assign_tiled(pts, cents, block_n=bn)
    want = ref.lloyd_assign_tiled_ref(pts, cents, bn, tps)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    for g, w, tol in zip(got[1:], want[1:], (1e-6, 1e-5, 1e-5, 1e-5, 0)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=tol)
    # reduced super-tile sums equal the accumulated kernel's totals
    a2, md2, sums2, counts2 = ops.lloyd_assign(pts, cents)
    np.testing.assert_allclose(np.asarray(got[4].sum(0)), np.asarray(sums2),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got[5].sum(0)),
                                  np.asarray(counts2))


def test_lloyd_assign_tiled_hierarchy_fires_above_floor():
    """Above 8 tiles the accumulators are per-SUPER (n_super ≈ √n_tiles),
    capping the footprint the flat layout paid per tile."""
    n, d, k, bn = 2048, 3, 5, 128
    grid = -(-n // bn)                       # 16 tiles
    tps = bounds.tiles_per_super(grid)
    assert 1 < tps < grid
    pts = jax.random.normal(jax.random.PRNGKey(7), (n, d))
    cents = jax.random.normal(jax.random.PRNGKey(8), (k, d))
    got = ops.lloyd_assign_tiled(pts, cents, block_n=bn)
    assert got[4].shape == (-(-grid // tps), k, d)
    want = ref.lloyd_assign_tiled_ref(pts, cents, bn, tps)
    np.testing.assert_allclose(np.asarray(got[4]), np.asarray(want[4]),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got[5]), np.asarray(want[5]))


def _no_prune_prev(n, grid, k, d, bn):
    """Carry arrays that make the per-point gate a no-op (lb = -inf) and
    carry recognizable values for skipped blocks."""
    z = jnp.zeros
    n_super = -(-grid // bounds.tiles_per_super(grid))
    return dict(delta=z((k,)), thresh=jnp.full((grid,), jnp.inf),
                absorb=z((grid,)), pa=z((n,), jnp.int32), pmd=z((n,)),
                plb=jnp.full((n,), -jnp.inf), pp=z((grid,)), pg=z((grid,)),
                pss=z((n_super, k, d)), psc=z((n_super, k)))


def test_lloyd_assign_gated_all_active_bitwise_equals_tiled():
    n, d, k, bn = 1000, 5, 7, 128
    pts = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    cents = jax.random.normal(jax.random.PRNGKey(3), (k, d))
    nrm = ops.point_norms(pts)
    grid = -(-n // bn)
    tiled = ops.lloyd_assign_tiled(pts, cents, norms=nrm, block_n=bn)
    pv = _no_prune_prev(n, grid, k, d, bn)
    gated = ops.lloyd_assign_gated(
        pts, cents, nrm, pv["delta"], pv["thresh"], pv["absorb"], pv["pa"],
        pv["pmd"], pv["plb"], pv["pp"], pv["pg"], pv["pss"], pv["psc"],
        jnp.ones((grid,), bool), block_n=bn)
    a, md, lb, part, gap, ssums, scounts, pruned, skipped = gated
    for g, t in zip((a, md, part, gap, ssums, scounts), tiled):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(t))
    assert int(skipped) == 0
    assert float(jnp.sum(pruned)) == 0.0   # thresh=+inf: nothing prunes


def test_lloyd_assign_gated_skipping_carries_previous_blocks():
    """Inactive tiles keep ALL aliased outputs bitwise; with unchanged
    centroids the carried values equal a recompute, so the full outputs are
    bitwise the tiled kernel's."""
    n, d, k, bn = 1024, 3, 5, 128
    pts = jax.random.normal(jax.random.PRNGKey(4), (n, d))
    cents = jax.random.normal(jax.random.PRNGKey(5), (k, d))
    nrm = ops.point_norms(pts)
    grid = -(-n // bn)
    assert bounds.tiles_per_super(grid) == 1   # flat: masks are super-aligned
    prev = ops.lloyd_assign_tiled(pts, cents, norms=nrm, block_n=bn)
    pv = _no_prune_prev(n, grid, k, d, bn)
    active = jnp.arange(grid) % 3 == 0
    gated = ops.lloyd_assign_gated(
        pts, cents, nrm, pv["delta"], pv["thresh"], pv["absorb"],
        prev[0], prev[1], pv["plb"], prev[2], prev[3], prev[4], prev[5],
        active, block_n=bn)
    a, md, lb, part, gap, ssums, scounts, pruned, skipped = gated
    # active tiles recompute values bitwise-equal to the carries (centroids
    # unchanged + thresh=+inf disables the per-point path), skipped tiles
    # alias them — so every output equals the tiled kernel's
    for g, t in zip((a, md, part, gap, ssums, scounts), prev):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(t))
    # skipped tiles' lb and pruned counters keep the donated carries
    act_pt = np.repeat(np.asarray(active), bn)[:n]
    np.testing.assert_array_equal(np.asarray(lb)[~act_pt],
                                  np.asarray(pv["plb"])[~act_pt])
    np.testing.assert_array_equal(np.asarray(pruned)[~np.asarray(active)],
                                  0.0)
    assert int(skipped) == grid - int(jnp.sum(active))


def test_lloyd_assign_gated_per_point_prune_is_bitwise_exact():
    """A real carried state + zero movement: most points prune, and every
    output still equals the all-fresh tiled kernel's bitwise (the per-point
    short-circuit is a value-noop)."""
    n, d, k, bn = 1024, 3, 5, 128
    pts = jax.random.normal(jax.random.PRNGKey(14), (n, d))
    cents = jax.random.normal(jax.random.PRNGKey(15), (k, d))
    nrm = ops.point_norms(pts)
    grid = -(-n // bn)
    prev = ops.lloyd_assign_tiled(pts, cents, norms=nrm, block_n=bn)
    a0, md0 = prev[0], prev[1]
    # true per-point lb from the oracle (second-best distance)
    d2 = np.array(ref._d2(pts, cents))
    d2[np.arange(n), np.asarray(a0)] = np.inf
    plb = jnp.asarray(np.sqrt(d2.min(axis=1)), jnp.float32)
    gated = ops.lloyd_assign_gated(
        pts, cents, nrm, jnp.zeros((k,)), jnp.full((grid,), 1e-3),
        jnp.zeros((grid,)), a0, md0, plb, prev[2], prev[3], prev[4],
        prev[5], jnp.ones((grid,), bool), block_n=bn)
    a, md, lb, part, gap, ssums, scounts, pruned, skipped = gated
    assert float(jnp.sum(pruned)) > 0.5 * n     # the fine level fires
    for g, t in zip((a, md, part, ssums, scounts),
                    (prev[0], prev[1], prev[2], prev[4], prev[5])):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(t))


def test_lloyd_assign_gated_batched_matches_single():
    B, n, d, k, bn = 3, 512, 2, 4, 128
    keys = jax.random.split(jax.random.PRNGKey(6), 4)
    pts = jax.random.normal(keys[0], (B, n, d))
    cents = jax.random.normal(keys[1], (B, k, d))
    nrm = jax.vmap(ops.point_norms)(pts)
    grid = -(-n // bn)
    prev = jax.vmap(lambda p, c, nr: ops.lloyd_assign_tiled(
        p, c, norms=nr, block_n=bn))(pts, cents, nrm)
    pv = _no_prune_prev(n, grid, k, d, bn)
    bcast = lambda x: jnp.broadcast_to(x[None], (B,) + x.shape)
    active = jnp.arange(grid)[None, :] % (jnp.arange(B)[:, None] + 2) == 0
    args = (pts, cents, nrm, bcast(pv["delta"]), bcast(pv["thresh"]),
            bcast(pv["absorb"]), prev[0], prev[1], bcast(pv["plb"]),
            prev[2], prev[3], prev[4], prev[5], active)
    out = jax.vmap(lambda p, c, nr, dl, th, ab, pa, pm, pl, pp, pg, ts, tc,
                   ac: ops.lloyd_assign_gated(p, c, nr, dl, th, ab, pa, pm,
                                              pl, pp, pg, ts, tc, ac,
                                              block_n=bn))(*args)
    for b in range(B):
        single = ops.lloyd_assign_gated(*[x[b] for x in args], block_n=bn)
        for x, y in zip([o[b] for o in out], single):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_assign_gate_model_requires_unmoved_assigned_centroids():
    """A centroid that moved even slightly keeps every tile it owns active —
    the carried min_d2 would otherwise be stale."""
    pts = _coherent(n=4096, seed=14)
    be = FusedBackend()
    cache = be.prologue(pts, m=4)
    tile = be.seed_tile(4096, 2, 4)
    cents = jnp.asarray(pts[::1024][:4], jnp.float32)
    first = be.assign_update(pts, cents, None, cache.norms, cache=cache)
    st = first.state
    # no movement at all: every occupied tile with a healthy gap may skip
    delta0 = jnp.zeros((4,), jnp.float32)
    active0 = bounds.assign_active_tiles(delta0, cents, st, cache)
    # every centroid moved: nothing may skip
    delta1 = jnp.full((4,), 0.5, jnp.float32)
    active1 = bounds.assign_active_tiles(delta1, cents, st, cache)
    assert bool(jnp.all(active1))
    assert int(jnp.sum(active0)) <= int(jnp.sum(active1))


# ---------------------------------------------------------------------------
# bound-state edge cases (ISSUE 5 satellite): k=1, n < one tile, multi-skip
# decay, reseed-vs-gate interaction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
def test_bounded_fit_k1_is_exact(backend):
    """k = 1 has no runner-up: per-point lb and tile gaps are +inf, so after
    the first iteration everything is provably stable — and the gated fit
    must still be bitwise the ungated one."""
    pts = _coherent(n=4096, k=1, seed=21)
    init = pts[:1]
    on = ClusterEngine(backend).fit(pts, init, max_iters=5, tol=-1.0)
    off = ClusterEngine(backend, bounds=False).fit(pts, init, max_iters=5,
                                                   tol=-1.0)
    np.testing.assert_array_equal(np.asarray(on.centroids),
                                  np.asarray(off.centroids))
    np.testing.assert_array_equal(np.asarray(on.assignment),
                                  np.asarray(off.assignment))
    assert float(on.inertia) == float(off.inertia)
    # once the single centroid stops moving the fine level prunes everything
    assert int(on.pruned[-1]) == 4096, np.asarray(on.pruned)


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_bounded_fit_smaller_than_one_tile(backend):
    """n below the 128-lane tile floor: one padded tile, one super — the
    whole hierarchy degenerates without breaking exactness."""
    pts = jnp.asarray(blobs(100, 2, 3, seed=22)[0])
    init = pts[:3]
    on = ClusterEngine(backend).fit(pts, init, max_iters=6, tol=-1.0)
    off = ClusterEngine(backend, bounds=False).fit(pts, init, max_iters=6,
                                                   tol=-1.0)
    np.testing.assert_array_equal(np.asarray(on.centroids),
                                  np.asarray(off.centroids))
    assert float(on.inertia) == float(off.inertia)
    s = ClusterEngine(backend).seed(jax.random.PRNGKey(23), pts, 3)
    s_off = ClusterEngine(backend, bounds=False).seed(jax.random.PRNGKey(23),
                                                      pts, 3)
    np.testing.assert_array_equal(np.asarray(s.indices),
                                  np.asarray(s_off.indices))


def test_decay_gap_stays_valid_across_three_plus_skips():
    """A tile skipped for >= 3 consecutive iterations carries a gap decayed
    by each step's max movement; when centroids then stop moving bitwise the
    carried state is still exact (pinned against the ungated fit), and the
    per-iteration skip telemetry shows the multi-skip streak."""
    pts = _coherent(n=2 ** 15, d=8, k=16, seed=24)
    eng = ClusterEngine("fused")
    seeds = eng.seed(jax.random.PRNGKey(25), pts, 16).centroids
    res = eng.fit(pts, seeds, max_iters=12, tol=-1.0)
    off = ClusterEngine("fused", bounds=False).fit(pts, seeds, max_iters=12,
                                                   tol=-1.0)
    np.testing.assert_array_equal(np.asarray(res.centroids),
                                  np.asarray(off.centroids))
    assert float(res.inertia) == float(off.inertia)
    skips = np.asarray(res.skipped)
    # at least one run of >= 3 consecutive iterations with skipped tiles
    streak = best = 0
    for s in skips:
        streak = streak + 1 if s > 0 else 0
        best = max(best, streak)
    assert best >= 3, skips
    # the unit-level property behind it: decayed gaps never exceed what
    # per-step decay justifies
    gap = jnp.asarray([5.0, 3.0])
    active = jnp.asarray([False, False])
    g = gap
    for _ in range(3):
        g = bounds.decay_gap(g, active, jnp.zeros_like(g), jnp.asarray(1.0))
    np.testing.assert_allclose(np.asarray(g), [2.0, 0.0])


def test_reseed_invalidates_bounds_and_stays_exact():
    """empty='reseed' teleports a centroid: every point/tile whose bound
    could be stale must recompute (the reseeded cluster has delta > 0, so
    its points fail the own-centroid check and dmax spikes the thresholds)
    — and gated == ungated stays bitwise through the reseed."""
    pts = _coherent(n=2 ** 14, d=8, k=8, seed=26)
    # one far-away dead centroid forces a reseed on iteration 1
    cents = jnp.concatenate([pts[:7], jnp.full((1, 8), 500.0)])
    on = ClusterEngine("fused").fit(pts, cents, max_iters=10, tol=-1.0,
                                    empty="reseed")
    off = ClusterEngine("fused", bounds=False).fit(pts, cents, max_iters=10,
                                                   tol=-1.0, empty="reseed")
    np.testing.assert_array_equal(np.asarray(on.centroids),
                                  np.asarray(off.centroids))
    np.testing.assert_array_equal(np.asarray(on.assignment),
                                  np.asarray(off.assignment))
    assert float(on.inertia) == float(off.inertia)
    # the reseed's teleport (huge dmax) must disable pruning on the next
    # iteration: no point can clear a threshold scaled by the jump
    skips = np.asarray(on.skipped)
    prunes = np.asarray(on.pruned)
    assert skips[1] == 0 and prunes[1] == 0, (skips, prunes)
    # pruning resumes once the split settles
    assert prunes[2:].sum() > 0, prunes


# ---------------------------------------------------------------------------
# bf16 mini-batch streaming (satellite)
# ---------------------------------------------------------------------------


def test_minibatch_bf16_streams_and_matches_fp32_quality():
    n, d, k, batch = 8192, 2, 8, 512
    full = jnp.asarray(blobs(n, d, k, seed=15)[0])
    np_pts = np.asarray(full)

    def read_fn(step):
        lo = (step * batch) % n
        return np_pts[lo:lo + batch]

    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(12), full[:512],
                                        k).centroids
    f32 = ClusterEngine("fused").fit_minibatch(seeds, read_fn, n_batches=24)
    b16 = ClusterEngine("fused", precision="bf16").fit_minibatch(
        seeds, read_fn, n_batches=24)
    phi32 = float(quality.inertia(full, f32.centroids))
    phi16 = float(quality.inertia(full, b16.centroids))
    assert abs(phi16 - phi32) / phi32 < 0.15, (phi16, phi32)


def test_minibatch_bf16_jaxpr_streams_bf16():
    from repro.core import engine as eng_mod
    cents = jnp.zeros((4, 2), jnp.float32)
    counts = jnp.zeros((4,), jnp.float32)
    batch = jnp.zeros((256, 2), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda c, n, b: eng_mod.minibatch_step(c, n, b, FusedBackend(),
                                               "bf16"))(cents, counts, batch)
    assert "bf16" in str(jaxpr.jaxpr)


def test_minibatch_order_morton_returns_batch_row_order():
    n, d, k, batch = 4096, 2, 4, 512
    full = jnp.asarray(blobs(n, d, k, seed=16)[0])
    np_pts = np.asarray(full)

    def read_fn(step):
        lo = (step * batch) % n
        return np_pts[lo:lo + batch]

    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(13), full[:512],
                                        k).centroids
    res = ClusterEngine("fused").fit_minibatch(seeds, read_fn, n_batches=8,
                                               order="morton")
    assert res.assignment.shape == (batch,)
    # the last batch's assignment is in the BATCH's own row order: its
    # inertia against the returned centroids must sit within the one-step
    # centroid-update drift of the reported (pre-update) inertia — a
    # scrambled (non-inverted) assignment would be off by >10x on blobs
    last = jnp.asarray(read_fn(7))
    diff = last - res.centroids[res.assignment]
    phi = float(jnp.sum(jnp.sum(diff * diff, axis=1)))
    np.testing.assert_allclose(phi, float(res.inertia), rtol=0.05)


# ---------------------------------------------------------------------------
# k-means|| tiled weighted reduce (satellite)
# ---------------------------------------------------------------------------


def test_weighted_tiled_seeding_respects_zero_weights():
    pts = jnp.asarray(blobs(512, 2, 4, seed=17)[0])
    w = jnp.where(jnp.arange(512) < 256, 1.0, 0.0)
    for backend in ("fused", "pallas"):
        res = ClusterEngine(backend).seed(jax.random.PRNGKey(14), pts, 6,
                                          weights=w, sampler="tiled")
        idx = np.asarray(res.indices)
        assert (idx < 256).all(), idx


def test_kmeans_parallel_reduce_has_no_full_n_cumsum():
    """The k-means|| weighted reduce now draws with the tiled sampler: no
    cumsum over the full candidate axis may appear in the traced program
    once the candidate set spans multiple tiles."""
    from repro.core.kmeans_parallel import kmeans_parallel_init
    from repro.kernels.ops import choose_block_n
    n, d, k, rounds = 4096, 2, 4, 4
    l = 2 * k
    n_cand = rounds * l + 1
    pts = jnp.zeros((n, d), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda kk, pp: kmeans_parallel_init(kk, pp, k, rounds=rounds))(
        jax.random.PRNGKey(0), pts)
    import tests.test_engine as te
    sizes = set()
    for eqn in te._iter_eqns(jaxpr.jaxpr):
        if "cumsum" in eqn.primitive.name:
            sizes.add(eqn.invars[0].aval.shape)
    assert (n_cand,) not in sizes, sizes


def test_kmeans_parallel_quality_with_tiled_reduce():
    pts = jnp.asarray(blobs(4096, 2, 8, seed=18)[0])
    from repro.core.kmeans_parallel import kmeans_parallel_init
    res = kmeans_parallel_init(jax.random.PRNGKey(15), pts, 8)
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < 4096)).all()
    assert len(set(idx.tolist())) == 8
    phi = float(quality.inertia(pts, res.centroids))
    rand = jnp.asarray(pts[np.random.default_rng(0).choice(4096, 8)])
    assert phi < 2.0 * float(quality.inertia(pts, rand)) + 1e-6


# ---------------------------------------------------------------------------
# bench schema gate (ISSUE 5 satellite): the CI smoke must fail loudly when
# a BENCH_round section loses its prune/accumulator columns
# ---------------------------------------------------------------------------


def test_bench_schema_checker_guards_prune_columns():
    import json
    import pathlib

    from benchmarks.check_schema import check_file, check_payload

    base = (pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
            / "BENCH_round.json")
    assert check_file(base) == []            # the checked-in baseline passes
    payload = json.loads(base.read_text())
    stripped = {"rows": [{k: v for k, v in r.items() if k != "prune_rate"}
                         for r in payload["rows"]]}
    errs = check_payload("round", stripped)
    assert errs and all("prune_rate" in e for e in errs), errs
    # a section that silently disappears is also an error
    only_seed = {"rows": [r for r in payload["rows"]
                          if r["bench"] == "round_traffic"]}
    errs = check_payload("round", only_seed)
    assert any("never emitted" in e for e in errs), errs


# ---------------------------------------------------------------------------
# serve/kvquant ordering plumb (satellite)
# ---------------------------------------------------------------------------


def test_kvquant_codebook_accepts_order():
    from repro.serve import kvquant
    key = jax.random.PRNGKey(0)
    vecs = jax.random.normal(key, (1024, 16))
    cb = kvquant.build_codebook(key, vecs, n_sub=4, n_codes=16,
                                lloyd_iters=3, order="morton")
    assert cb.centroids.shape == (4, 16, 4)
    pq = kvquant.PQCache(kvquant.encode(vecs, cb), cb)
    err = float(kvquant.reconstruction_error(vecs, pq))
    base = kvquant.build_codebook(key, vecs, n_sub=4, n_codes=16,
                                  lloyd_iters=3)
    base_err = float(kvquant.reconstruction_error(
        vecs, kvquant.PQCache(kvquant.encode(vecs, base), base)))
    assert err < 2.0 * base_err + 1e-6


# ---------------------------------------------------------------------------
# checkpointed gated fit (ISSUE 7): mid-fit resume is bitwise the
# uninterrupted run — the serialized carry IS the loop carry
# ---------------------------------------------------------------------------


def test_checkpointed_fit_matches_plain_bitwise(tmp_path):
    pts = _coherent(n=4096, seed=30)
    eng = ClusterEngine("fused")
    seeds = eng.seed(jax.random.PRNGKey(30), pts, 4).centroids
    plain = eng.fit(pts, seeds, max_iters=9, tol=-1.0)
    ck = eng.fit(pts, seeds, max_iters=9, tol=-1.0,
                 checkpoint_dir=tmp_path, checkpoint_every=3)
    np.testing.assert_array_equal(np.asarray(plain.centroids),
                                  np.asarray(ck.centroids))
    np.testing.assert_array_equal(np.asarray(plain.assignment),
                                  np.asarray(ck.assignment))
    assert float(plain.inertia) == float(ck.inertia)
    assert int(plain.n_iters) == int(ck.n_iters)
    np.testing.assert_array_equal(np.asarray(plain.skipped),
                                  np.asarray(ck.skipped))


def test_checkpointed_fit_resumes_mid_fit_bitwise(tmp_path):
    """Crash simulation: run to completion, drop the newest step dirs (as
    if the job died mid-run), re-invoke — the resumed run restores the
    latest surviving carry, replays the remaining iterations, and finishes
    bit-identical to the uninterrupted fit."""
    import shutil
    from repro.checkpoint.manager import CheckpointManager
    pts = _coherent(n=4096, seed=31)
    eng = ClusterEngine("fused")
    seeds = eng.seed(jax.random.PRNGKey(31), pts, 4).centroids
    plain = eng.fit(pts, seeds, max_iters=10, tol=-1.0)
    eng.fit(pts, seeds, max_iters=10, tol=-1.0,
            checkpoint_dir=tmp_path, checkpoint_every=2)
    mgr = CheckpointManager(tmp_path)
    steps = mgr.all_steps()
    assert steps[-1] == 10
    for step in steps[-2:]:                   # lose the last two checkpoints
        shutil.rmtree(tmp_path / f"step_{step:08d}")
    resumed = eng.fit(pts, seeds, max_iters=10, tol=-1.0,
                      checkpoint_dir=tmp_path, checkpoint_every=2)
    np.testing.assert_array_equal(np.asarray(plain.centroids),
                                  np.asarray(resumed.centroids))
    np.testing.assert_array_equal(np.asarray(plain.assignment),
                                  np.asarray(resumed.assignment))
    assert float(plain.inertia) == float(resumed.inertia)
    assert int(plain.n_iters) == int(resumed.n_iters)


def test_checkpointed_fit_detects_convergence(tmp_path):
    """A chunk that stops short of its target iteration means the loop
    converged: no further chunks run, and n_iters matches the plain fit."""
    from repro.checkpoint.manager import CheckpointManager
    pts = _coherent(n=4096, seed=32)
    eng = ClusterEngine("fused")
    seeds = eng.seed(jax.random.PRNGKey(32), pts, 4).centroids
    plain = eng.fit(pts, seeds, max_iters=30)
    assert int(plain.n_iters) < 30
    ck = eng.fit(pts, seeds, max_iters=30, checkpoint_dir=tmp_path,
                 checkpoint_every=4)
    assert int(ck.n_iters) == int(plain.n_iters)
    assert float(ck.inertia) == float(plain.inertia)
    # no checkpoints were written past convergence
    assert CheckpointManager(tmp_path).latest_step() <= int(plain.n_iters) + 4


def test_checkpointed_fit_rejects_unsupported_modes(tmp_path):
    from repro.core.guards import CheckpointError
    pts = _coherent(n=1024, seed=33)
    with pytest.raises(CheckpointError, match="bounds=True"):
        ClusterEngine("fused", bounds=False).fit(
            pts, pts[:4], max_iters=3, checkpoint_dir=tmp_path)
    w = jnp.ones((1024,), jnp.float32)
    with pytest.raises(CheckpointError, match="unweighted"):
        ClusterEngine("fused").fit(pts, pts[:4], max_iters=3, weights=w,
                                   checkpoint_dir=tmp_path)
