"""Pins the engine-wide round-counter contract (repro.core.telemetry):
fixed-length int32 counters, zero-filled slots for rounds that never ran,
and the rejection sampler's proposals/accepts relations — stated ONCE there
instead of per-test ad hoc checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import telemetry
from repro.core.engine import _REJECT_ATTEMPTS, ClusterEngine


def _pts(n=4096, d=8, seed=1):
    return jax.random.normal(jax.random.key(seed), (n, d), jnp.float32)


# ---------------------------------------------------------------------------
# the checkers themselves reject contract violations
# ---------------------------------------------------------------------------


def test_check_counter_rejects_violations():
    with pytest.raises(AssertionError):
        telemetry.check_counter(None, 4)
    with pytest.raises(AssertionError):  # wrong length
        telemetry.check_counter(np.zeros(3, np.int32), 4)
    with pytest.raises(AssertionError):  # wrong dtype
        telemetry.check_counter(np.zeros(4, np.float32), 4)
    with pytest.raises(AssertionError):  # negative
        telemetry.check_counter(np.array([1, -1, 0, 0], np.int32), 4)
    with pytest.raises(AssertionError):  # non-zero past convergence
        telemetry.check_converged_zeros(np.array([2, 1, 1, 0], np.int32), 2, 4)
    telemetry.check_converged_zeros(np.array([2, 1, 0, 0], np.int32), 2, 4)


def test_check_rejection_counters_rejects_violations():
    ok_p = np.array([0, 1, 2, 1], np.int32)
    ok_a = np.array([0, 1, 0, 1], np.int32)
    telemetry.check_rejection_counters(ok_p, ok_a, 4, max_attempts=8)
    with pytest.raises(AssertionError):  # proposed on round 0
        telemetry.check_rejection_counters(
            np.array([1, 1, 1, 1], np.int32), ok_a, 4, max_attempts=8)
    with pytest.raises(AssertionError):  # accepts not 0/1
        telemetry.check_rejection_counters(
            ok_p, np.array([0, 2, 0, 1], np.int32), 4, max_attempts=8)
    with pytest.raises(AssertionError):  # over the truncation depth
        telemetry.check_rejection_counters(
            np.array([0, 9, 1, 1], np.int32), ok_a, 4, max_attempts=8)


# ---------------------------------------------------------------------------
# engine results obey the contract
# ---------------------------------------------------------------------------


def test_seed_counters_obey_contract():
    for sampler in ("tiled", "rejection"):
        res = ClusterEngine("fused").seed(jax.random.key(0), _pts(), 8,
                                          sampler=sampler)
        telemetry.check_counter(res.skipped, 8, "skipped")
        telemetry.check_counter(res.pruned, 8, "pruned")


def test_rejection_counters_obey_contract():
    res = ClusterEngine("fused").seed(jax.random.key(0), _pts(), 12,
                                      sampler="rejection", refresh_block=4)
    telemetry.check_rejection_counters(res.proposals, res.accepts, 12,
                                       max_attempts=_REJECT_ATTEMPTS)
    # non-rejection samplers don't grow the counters
    tiled = ClusterEngine("fused").seed(jax.random.key(0), _pts(), 12,
                                        sampler="tiled")
    assert tiled.proposals is None and tiled.accepts is None


def test_fit_counters_zero_filled_past_convergence():
    pts = _pts(n=2048, d=2, seed=3)
    seeds = ClusterEngine("fused").seed(jax.random.key(1), pts, 4).centroids
    res = ClusterEngine("fused").fit(pts, seeds, max_iters=25)
    it = int(res.n_iters)
    assert it < 25
    telemetry.check_converged_zeros(res.skipped, it, 25, "skipped")
    telemetry.check_converged_zeros(res.pruned, it, 25, "pruned")
