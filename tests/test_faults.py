"""Fault-injection matrix (ISSUE 7 tentpole): every injected fault either
RECOVERS BITWISE (the guarded loop heals and the final result equals a
never-corrupted run's) or raises a typed ClusteringError subclass — never a
silent wrong answer. Covers traced-compute corruption (NaN'd tiles, poisoned
bound state, lost psum contributions, broken rejection envelopes), forced
kernel failures walking the backend fallback chain, and host-side pipeline
deaths."""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import telemetry
from repro.core.engine import ClusterEngine, MeshBackend
from repro.core.guards import (ClusteringError, InvalidInputError,
                               KernelFailureError, PipelineError)
from repro.data import DataPipeline
from repro.data.synthetic import blobs
from repro.testing import (FaultSpec, flaky_read_fn, force_kernel_failure,
                           kill_prefetch)


def _coherent(n=16384, d=2, k=8, seed=0):
    pts, labels = blobs(n, d, k, seed=seed, spread=0.05)
    return jnp.asarray(pts[np.argsort(labels, kind="stable")])


def _same_seed(a, b):
    np.testing.assert_array_equal(np.asarray(a.centroids),
                                  np.asarray(b.centroids))
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.min_d2), np.asarray(b.min_d2))


def _same_fit(a, b):
    np.testing.assert_array_equal(np.asarray(a.centroids),
                                  np.asarray(b.centroids))
    np.testing.assert_array_equal(np.asarray(a.assignment),
                                  np.asarray(b.assignment))
    assert float(a.inertia) == float(b.inertia)
    assert int(a.n_iters) == int(b.n_iters)


# ---------------------------------------------------------------------------
# in-flight corruption: the guarded loops detect, heal, and recover BITWISE
# ---------------------------------------------------------------------------


def test_seed_nan_tile_recovers_bitwise():
    """NaN'd D^2 rows poison the round total; the heal refolds the chosen
    prefix ungated and the final seeds are bit-identical to a clean run."""
    pts = _coherent()
    eng = ClusterEngine("fused", validate="raise")
    clean = eng.seed(jax.random.PRNGKey(1), pts, 8)
    assert clean.recovered is not None
    telemetry.check_recovered(clean.recovered, 8, expect=np.zeros(8))
    hurt = eng.seed(jax.random.PRNGKey(1), pts, 8,
                    _fault=FaultSpec("nan_tile", round=2))
    _same_seed(clean, hurt)
    telemetry.check_recovered(hurt.recovered, 8)
    assert int(np.asarray(hurt.recovered)[1]) == 1   # round m at slot m-1


def test_seed_poisoned_bound_state_recovers_when_witnessed():
    """A NaN'd carried partial in a tile the gate SKIPS is summed straight
    into the round total (skipped tiles reuse the carry) — the exact blind
    spot a correctness-only reading would miss. Detection fires on a round
    with skips; recovery is bitwise either way."""
    pts = _coherent()
    eng = ClusterEngine("fused", validate="raise")
    clean = eng.seed(jax.random.PRNGKey(1), pts, 8)
    assert int(np.asarray(clean.skipped)[6]) > 0    # round 7 skips tiles
    hurt = eng.seed(jax.random.PRNGKey(1), pts, 8,
                    _fault=FaultSpec("nan_state", round=7))
    _same_seed(clean, hurt)
    assert int(np.asarray(hurt.recovered)[6]) == 1
    # the same poison in a round that recomputes every tile is overwritten
    # before anything reads it: harmless, not flagged — and still bitwise
    active = eng.seed(jax.random.PRNGKey(1), pts, 8,
                      _fault=FaultSpec("nan_state", round=1))
    _same_seed(clean, active)


# REPRO_FAULTS=1 (the dedicated CI step) widens the matrix to every
# injectable fit iteration; the default tier-1 run keeps a representative
# pair so the suite stays fast.
_FIT_FAULT_ROUNDS = ((2, 3, 4, 5, 6)
                     if os.environ.get("REPRO_FAULTS", "") == "1"
                     else (2, 4))


@pytest.mark.parametrize("kind", ["zero_counts", "nan_state"])
@pytest.mark.parametrize("rd", _FIT_FAULT_ROUNDS)
def test_fit_faults_recover_bitwise(kind, rd):
    """A halved psum contribution (lost shard) or NaN'd bound state trips
    the per-iteration health check; the heal runs one ungated round,
    rebuilds the bound state, and the fit converges bit-identically."""
    pts = _coherent()
    eng = ClusterEngine("fused", validate="raise")
    seeds = eng.seed(jax.random.PRNGKey(1), pts, 8).centroids
    clean = eng.fit(pts, seeds, max_iters=8, tol=-1.0)
    telemetry.check_recovered(clean.recovered, 8, expect=np.zeros(8))
    hurt = eng.fit(pts, seeds, max_iters=8, tol=-1.0,
                   _fault=FaultSpec(kind, round=rd))
    _same_fit(clean, hurt)
    assert int(np.asarray(hurt.recovered)[rd]) == 1


def test_fit_guard_off_returns_no_recovery_telemetry():
    pts = _coherent(n=4096)
    eng = ClusterEngine("fused", validate="off")
    seeds = eng.seed(jax.random.PRNGKey(2), pts, 4).centroids
    res = eng.fit(pts, seeds, max_iters=4)
    assert res.recovered is None and seeds is not None


def test_rejection_envelope_corruption_replays_bitwise():
    """A negative stale partial breaks the dominance precondition; the
    guard rebuilds the STALE envelope (refreshed prefix only) before
    proposing, so even the proposal/accept counters replay bitwise."""
    pts = _coherent(n=8192)
    eng = ClusterEngine("fused", validate="raise")
    clean = eng.seed(jax.random.PRNGKey(2), pts, 8, sampler="rejection")
    hurt = eng.seed(jax.random.PRNGKey(2), pts, 8, sampler="rejection",
                    _fault=FaultSpec("neg_envelope", round=3))
    _same_seed(clean, hurt)
    np.testing.assert_array_equal(np.asarray(clean.proposals),
                                  np.asarray(hurt.proposals))
    np.testing.assert_array_equal(np.asarray(clean.accepts),
                                  np.asarray(hurt.accepts))
    rec = np.asarray(hurt.recovered)
    assert rec[3] == 1 and rec.sum() == 1
    telemetry.check_rejection_counters(hurt.proposals, hurt.accepts, 8,
                                       max_attempts=8,
                                       recovered=hurt.recovered)


# REPRO_FAULTS=1 widens the torn-coarse-aggregate matrix to every injectable
# rejection round; tier-1 keeps one representative round.
_SUPER_FAULT_ROUNDS = ((2, 3, 4, 5, 6)
                       if os.environ.get("REPRO_FAULTS", "") == "1"
                       else (3,))


@pytest.mark.parametrize("rd", _SUPER_FAULT_ROUNDS)
def test_rejection_stale_super_heals_via_prefix_refold(rd):
    """A torn coarse aggregate (every tile partial backing the LAST super
    NaN'd) trips the same fp-validity guard as neg_envelope; the heal
    refolds the refreshed prefix, and because the super-tile proposal state
    is DERIVED from the healed partials each round, the coarse-to-fine draw
    — indices, proposal/accept counters, AND the tightened/supers counters —
    replays bitwise against a never-corrupted run."""
    pts = _coherent(n=8192)
    eng = ClusterEngine("fused", validate="raise")
    clean = eng.seed(jax.random.PRNGKey(2), pts, 8, sampler="rejection",
                     proposal="hier")
    hurt = eng.seed(jax.random.PRNGKey(2), pts, 8, sampler="rejection",
                    proposal="hier",
                    _fault=FaultSpec("stale_super", round=rd))
    _same_seed(clean, hurt)
    for name in ("proposals", "accepts", "tightened", "supers"):
        np.testing.assert_array_equal(np.asarray(getattr(clean, name)),
                                      np.asarray(getattr(hurt, name)))
    rec = np.asarray(hurt.recovered)
    assert rec[rd] == 1 and rec.sum() == 1
    telemetry.check_hier_counters(hurt.tightened, hurt.supers,
                                  hurt.proposals, 8, hier=True)


def test_mesh_guarded_fit_recovers_bitwise():
    """The health predicate is psum-replicated: every shard takes the same
    heal branch, and the mesh fit recovers bit-identically too."""
    mesh = jax.make_mesh((1,), ("data",))
    pts = _coherent(n=8192)
    eng = ClusterEngine(MeshBackend(mesh=mesh, axes=("data",)),
                        validate="raise")
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(3), pts,
                                        8).centroids
    clean = eng.fit(pts, seeds, max_iters=6, tol=-1.0)
    hurt = eng.fit(pts, seeds, max_iters=6, tol=-1.0,
                   _fault=FaultSpec("zero_counts", round=2))
    _same_fit(clean, hurt)
    assert int(np.asarray(hurt.recovered)[2]) == 1


# ---------------------------------------------------------------------------
# kernel failures: fallback chain pallas -> fused -> reference, typed at the
# end of the chain — with provenance
# ---------------------------------------------------------------------------


def test_kernel_failure_walks_fallback_chain():
    pts = _coherent(n=4096)
    eng = ClusterEngine("pallas", validate="raise")
    want = ClusterEngine("fused", validate="raise").seed(
        jax.random.PRNGKey(4), pts, 4)
    with warnings.catch_warnings(record=True) as wlist:
        warnings.simplefilter("always")
        with force_kernel_failure("injected launch failure"):
            got = eng.seed(jax.random.PRNGKey(4), pts, 4)
    _same_seed(want, got)
    assert [e[:2] for e in eng.fallback_events] == [("pallas", "fused")]
    assert "injected launch failure" in eng.fallback_events[0][2]
    assert eng.last_backend.name == "fused"
    msgs = [str(w.message) for w in wlist
            if issubclass(w.category, RuntimeWarning)]
    assert len(msgs) == 1 and "falling back to 'fused'" in msgs[0]


def test_kernel_failure_warns_only_once():
    pts = _coherent(n=2048)
    eng = ClusterEngine("pallas")
    with warnings.catch_warnings(record=True) as wlist:
        warnings.simplefilter("always")
        with force_kernel_failure():
            eng.seed(jax.random.PRNGKey(5), pts, 4)
            eng.seed(jax.random.PRNGKey(6), pts, 4)
    msgs = [w for w in wlist if issubclass(w.category, RuntimeWarning)]
    assert len(msgs) == 1
    assert len(eng.fallback_events) == 2     # provenance still records both


def test_exhausted_fallback_chain_raises_typed():
    """reference has nowhere to fall: a failure that survives the whole
    chain surfaces as the typed KernelFailureError (a ClusteringError),
    not a silent result."""
    eng = ClusterEngine("pallas")

    def always_fail(be):
        raise KernelFailureError(f"dead on {be.name}")

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(KernelFailureError, match="dead on reference"):
            eng._run(always_fail)
    assert [e[:2] for e in eng.fallback_events] == [
        ("pallas", "fused"), ("fused", "reference")]
    assert isinstance(KernelFailureError("x"), ClusteringError)
    # the terminal link is kernel-free BY CONSTRUCTION: the reference
    # backend computes inline jnp and still serves under a forced failure
    pts = _coherent(n=1024)
    with force_kernel_failure("dead"):
        res = ClusterEngine("reference").seed(jax.random.PRNGKey(7), pts, 4)
    assert np.isfinite(np.asarray(res.centroids)).all()


def test_mesh_kernel_failure_swaps_local_backend():
    """On a mesh the LOCAL compute backend is what can kernel-fail; the
    walker swaps it in place, keeping the mesh wrapper (and its
    collectives) intact."""
    from repro.core.engine import PallasBackend
    mesh = jax.make_mesh((1,), ("data",))
    pts = _coherent(n=4096)
    eng = ClusterEngine(MeshBackend(mesh=mesh, axes=("data",),
                                    local=PallasBackend()))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with force_kernel_failure():
            res = eng.fit(pts, pts[:4], max_iters=3)
    assert eng.fallback_events[0][:2] == ("pallas", "fused")
    assert eng.last_backend.distributed
    assert eng.last_backend.local.name == "fused"
    assert np.isfinite(float(res.inertia))


def test_fused_backend_is_kernel_free():
    """The fused (and reference) backends compute inline jnp — they are
    fallback TARGETS, immune to kernel launch failures by construction."""
    pts = _coherent(n=2048)
    eng = ClusterEngine("fused")
    with force_kernel_failure("boom"):
        res = eng.fit(pts, pts[:4], max_iters=3)
    assert eng.fallback_events == []
    assert np.isfinite(float(res.inertia))


# ---------------------------------------------------------------------------
# entry guards: garbage in -> typed error (or sanitized), never NaN out
# ---------------------------------------------------------------------------


def test_malformed_inputs_raise_typed_errors():
    pts = _coherent(n=512)
    eng = ClusterEngine("fused", validate="raise")
    bad = np.asarray(pts).copy()
    bad[3, 0] = np.inf
    with pytest.raises(InvalidInputError, match="non-finite"):
        eng.seed(jax.random.PRNGKey(0), bad, 4)
    with pytest.raises(InvalidInputError, match="0 < k <= n"):
        eng.seed(jax.random.PRNGKey(0), pts, 0)
    with pytest.raises(InvalidInputError, match="0 < k <= n"):
        eng.seed(jax.random.PRNGKey(0), pts[:3], 4)
    with pytest.raises(InvalidInputError, match="weights"):
        eng.seed(jax.random.PRNGKey(0), pts, 4,
                 weights=-np.ones(512, np.float32))
    with pytest.raises(InvalidInputError, match="non-finite"):
        eng.fit(pts, np.full((4, 2), np.nan, np.float32), max_iters=2)
    assert issubclass(InvalidInputError, (ClusteringError, ValueError))


def test_sanitize_policy_zeroes_rows_and_stays_bitwise_on_clean_input():
    pts = np.asarray(_coherent(n=2048))
    bad = pts.copy()
    bad[7] = np.nan
    san = ClusterEngine("fused", validate="sanitize")
    res = san.seed(jax.random.PRNGKey(8), bad, 4)
    assert np.isfinite(np.asarray(res.centroids)).all()
    # clean input passes through UNTOUCHED: sanitize == off bitwise
    a = san.seed(jax.random.PRNGKey(8), pts, 4)
    b = ClusterEngine("fused", validate="off").seed(jax.random.PRNGKey(8),
                                                    pts, 4)
    _same_seed(a, b)


# ---------------------------------------------------------------------------
# host-side pipeline faults
# ---------------------------------------------------------------------------


def test_transient_read_failures_are_retried():
    fails = {1: 2, 3: 1}      # step 1 flakes twice, step 3 once
    pipe = DataPipeline(
        flaky_read_fn(lambda s: {"x": np.full((4,), s)}, fail_steps=fails),
        prefetch=1, backoff=0.01)
    got = [next(iter(pipe))[0] for _ in range(5)]
    pipe.stop()
    assert got == [0, 1, 2, 3, 4]
    assert fails == {1: 0, 3: 0}             # every flake was consumed


def test_dead_prefetch_thread_raises_typed_pipeline_error():
    pipe = DataPipeline(lambda s: {"x": np.zeros(2)}, prefetch=1)
    it = iter(pipe)
    next(it)
    kill_prefetch(pipe)
    with pytest.raises(PipelineError) as ei:
        for _ in range(8):
            next(it)
    pipe.stop()
    assert ei.value.step is not None
    assert isinstance(ei.value, ClusteringError)


def test_minibatch_surfaces_pipeline_error_with_step():
    eng = ClusterEngine("fused")
    boom = 5

    def read_fn(step):
        if step == boom:
            raise IOError("storage gone")
        return np.random.default_rng(step).normal(size=(128, 2)).astype(
            np.float32)

    pipe = DataPipeline(read_fn, prefetch=1, retries=2, backoff=0.01)
    with pytest.raises(PipelineError, match="read_fn failed") as ei:
        eng.fit_minibatch(np.zeros((4, 2), np.float32), pipe, n_batches=16)
    assert ei.value.step == boom


# ---------------------------------------------------------------------------
# serving-index state corruption: a poisoned offset table must raise typed,
# never return silently-wrong neighbors
# ---------------------------------------------------------------------------


def _small_index():
    from repro.serve import IvfIndex
    pts, _ = blobs(1024, 8, 8, seed=3)
    return IvfIndex.build(jnp.asarray(pts), 8, block_n=128)


@pytest.mark.parametrize("kind", ["shifted_start", "short_count",
                                  "negative_count"])
def test_corrupt_list_offsets_raises_typed_on_search(kind):
    from repro.core.guards import CorruptedStateError
    from repro.testing.faults import corrupt_list_offsets

    idx = _small_index()
    qs = jnp.asarray(blobs(4, 8, 8, seed=4)[0])
    # sanity: the uncorrupted index serves
    idx.search(qs, 5, nprobe=8)
    bad = corrupt_list_offsets(idx, kind=kind)
    with pytest.raises(CorruptedStateError, match="rebuild the index"):
        bad.search(qs, 5, nprobe=8)
    # the check is always on — validate='off' relaxes input guards only
    with pytest.raises(CorruptedStateError):
        bad.search(qs, 5, nprobe=8, validate="off")


def test_ivf_search_survives_forced_kernel_failure_via_fallback():
    """A forced Pallas failure walks the scan dispatch down the fallback
    chain to the bitwise-identical ref twin instead of surfacing."""
    idx = _small_index()
    qs = jnp.asarray(blobs(4, 8, 8, seed=4)[0])
    clean = idx.search(qs, 5, nprobe=8, backend="pallas")
    with force_kernel_failure("ivf scan down"):
        with pytest.raises(KernelFailureError):
            idx.search(qs, 5, nprobe=8, backend="reference")
    # chain exhausted -> typed raise; pallas entry would need a non-forced
    # fused hop, which the force blocks too — both end typed, never silent
    hurt = idx.search(qs, 5, nprobe=8, backend="pallas")
    np.testing.assert_array_equal(np.asarray(clean.indices),
                                  np.asarray(hurt.indices))
