"""PQ flash-decode kernel (paper integration #1 as a TPU kernel): attention
over product-quantized KV codes == attention over the reconstructed cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pq_decode import hbm_bytes_model, pq_decode_attention
from repro.kernels.ref import flash_attention_ref
from repro.serve import kvquant


def _pq_cache(key, B, S, KH, hd, n_sub, n_codes=256):
    """Random codebooks + codes; returns (k_codes, v_codes, k_cb, v_cb,
    k_rec, v_rec) where *_rec is the exact reconstruction."""
    ks = jax.random.split(key, 4)
    dsub = hd // n_sub
    k_cb = jax.random.normal(ks[0], (KH, n_sub, n_codes, dsub), jnp.float32)
    v_cb = jax.random.normal(ks[1], (KH, n_sub, n_codes, dsub), jnp.float32)
    k_codes = jax.random.randint(ks[2], (B, S, KH, n_sub), 0, n_codes,
                                 jnp.int32).astype(jnp.uint8)
    v_codes = jax.random.randint(ks[3], (B, S, KH, n_sub), 0, n_codes,
                                 jnp.int32).astype(jnp.uint8)

    def rec(codes, cb):
        # (B,S,KH,n_sub) + (KH,n_sub,256,dsub) -> (B,S,KH,hd)
        out = []
        for h in range(KH):
            parts = [cb[h, s][codes[:, :, h, s]] for s in range(n_sub)]
            out.append(jnp.concatenate(parts, axis=-1))
        return jnp.stack(out, axis=2)

    return k_codes, v_codes, k_cb, v_cb, rec(k_codes, k_cb), rec(v_codes, v_cb)


CASES = [
    # (B, S, KH, G, hd, n_sub, block_k, cache_len)
    (2, 256, 2, 2, 32, 4, 128, 256),
    (1, 300, 4, 1, 64, 8, 128, 300),      # ragged S
    (2, 256, 2, 4, 64, 8, 64, 100),       # partial cache
    (1, 128, 1, 8, 128, 16, 128, 128),    # hd 128, 16 sub-spaces
]


@pytest.mark.parametrize("case", CASES)
def test_pq_decode_matches_reconstructed_attention(case):
    B, S, KH, G, hd, n_sub, block_k, cache_len = case
    H = KH * G
    key = jax.random.PRNGKey(0)
    kc, vc, kcb, vcb, k_rec, v_rec = _pq_cache(key, B, S, KH, hd, n_sub)
    q = jax.random.normal(jax.random.fold_in(key, 9), (B, 1, H, hd))

    got = pq_decode_attention(q, kc, vc, kcb, vcb,
                              jnp.asarray(cache_len, jnp.int32),
                              block_k=block_k, interpret=True)
    # oracle: ordinary attention over the reconstructed cache, masked to
    # cache_len (non-causal + explicit length == decode semantics)
    k_m = jnp.where((jnp.arange(S) < cache_len)[None, :, None, None],
                    k_rec, 0.0)
    want = flash_attention_ref(
        q, k_m[:, :cache_len], v_rec[:, :cache_len], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_pq_decode_end_to_end_with_kvquant():
    """Full pipeline: real KV -> kvquant codebooks/codes -> PQ attention is
    close to attention over the ORIGINAL cache (quality bound)."""
    B, S, KH, G, hd, n_sub = 1, 512, 2, 2, 64, 16
    H = KH * G
    key = jax.random.PRNGKey(1)
    # low-rank-ish KV, like real caches
    base = jax.random.normal(key, (8, hd))
    coef = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, 8))
    kv = coef @ base + 0.03 * jax.random.normal(
        jax.random.fold_in(key, 2), (B, S, KH, hd))
    k_cache = kv
    v_cache = jnp.roll(kv, 7, axis=1)

    def build(cache):
        cbs, codes = [], []
        for h in range(KH):
            cb = kvquant.build_codebook(jax.random.fold_in(key, 100 + h),
                                        cache[:, :, h].reshape(-1, hd),
                                        n_sub=n_sub)
            cbs.append(cb.centroids)
            codes.append(kvquant.encode(cache[:, :, h], cb))
        return (jnp.stack(cbs),
                jnp.stack(codes, axis=2).astype(jnp.uint8))

    k_cb, k_codes = build(k_cache)
    v_cb, v_codes = build(v_cache)

    q = jax.random.normal(jax.random.fold_in(key, 5), (B, 1, H, hd))
    got = pq_decode_attention(q, k_codes, v_codes, k_cb, v_cb,
                              jnp.asarray(S, jnp.int32), block_k=128,
                              interpret=True)
    want = flash_attention_ref(q, k_cache, v_cache, causal=False)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    # PQ @ 16 sub-spaces on rank-8+noise KV: ~0.26 relative output error
    # (quality/compression trade-off is charted in benchmarks/quality_parity)
    assert rel < 0.35, rel


def test_pq_bytes_model():
    m = hbm_bytes_model(B=128, S=32768, KH=32, hd=128, n_sub=16)
    assert m["compression"] > 10     # ~16x minus codebook overhead
