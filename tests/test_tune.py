"""Autotuner tests (ISSUE 8): cache round-trip + invalidation semantics,
the tune="off" bitwise guarantee, the warm-cache zero-measurement pin, and
the parameterized tiles_per_super / block_n plumbing."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bounds
from repro.core.engine import ClusterEngine, FusedBackend
from repro.core.guards import ClusteringError, CorruptedStateError
from repro.data.synthetic import blobs
from repro.kernels import ops
from repro.tune import (SCHEMA_VERSION, TuneCache, TuneRecord, backend_key,
                        measure, resolve, search)
from repro.tune.cache import record_key


def _points(n=512, d=2, k=8, seed=0):
    pts, _ = blobs(n, d, k, seed=seed)
    return jnp.asarray(pts)


# ---------------------------------------------------------------------------
# cache round-trip + invalidation (satellite 3)
# ---------------------------------------------------------------------------

def test_cache_round_trip(tmp_path):
    rec = search(2 ** 14, 8, 4)
    cache = TuneCache(tmp_path)
    cache.put(rec)
    path = cache.save()
    assert path is not None and path.exists()

    reloaded = TuneCache(tmp_path)
    got = reloaded.get(2 ** 14, 8, 4, "fused", "float32")
    assert got is not None
    assert got.source == "cache"          # provenance marks the hit path
    assert dataclasses.replace(got, source=rec.source, measured_ms=0.0) \
        == dataclasses.replace(rec, measured_ms=0.0)


def test_schema_version_bump_invalidates(tmp_path):
    cache = TuneCache(tmp_path)
    cache.put(search(2 ** 14, 8, 4))
    path = cache.save()
    raw = json.loads(path.read_text())
    raw["schema"] = SCHEMA_VERSION + 1
    path.write_text(json.dumps(raw))
    # a bumped schema silently invalidates (stale tuning is a perf
    # question): the cache loads EMPTY, no raise
    stale = TuneCache(tmp_path)
    assert stale.entries == {}
    assert stale.get(2 ** 14, 8, 4, "fused", "float32") is None


def test_geometry_mismatch_falls_back_to_heuristic(tmp_path):
    cache = TuneCache(tmp_path)
    cache.put(search(2 ** 14, 8, 4))
    path = cache.save()
    raw = json.loads(path.read_text())
    # hand-edit the entry's geometry out from under its key stamp
    (key, fields), = raw["entries"].items()
    fields["n"] = 12345
    path.write_text(json.dumps(raw))
    reloaded = TuneCache(tmp_path)
    assert key in reloaded.dropped        # stamped mismatch -> dropped
    assert reloaded.entries == {}
    # ...and the engine serves the shape from the heuristics, not a crash
    eng = ClusterEngine("fused", tune="cache", tune_dir=tmp_path)
    res = eng.seed(jax.random.PRNGKey(0), _points(), 8)
    assert res.tune is None
    assert res.centroids.shape == (8, 2)


def test_corrupted_cache_raises_typed(tmp_path):
    (tmp_path / "tune_cache.json").write_text("{not json!!")
    with pytest.raises(CorruptedStateError):
        TuneCache(tmp_path)
    # the typed error is part of the ClusteringError vocabulary and
    # surfaces through the engine entry point too, not a JSONDecodeError
    eng = ClusterEngine("fused", tune="cache", tune_dir=tmp_path)
    with pytest.raises(ClusteringError):
        eng.seed(jax.random.PRNGKey(0), _points(), 8)


def test_nearest_shape_fallback_prefers_exact(tmp_path):
    cache = TuneCache(tmp_path)
    far = dataclasses.replace(search(2 ** 16, 32, 16), source="model")
    near = dataclasses.replace(search(2 ** 14, 8, 4), source="model")
    cache.put(far)
    cache.put(near)
    exact = cache.get(2 ** 14, 8, 4, "fused", "float32")
    assert exact.source == "cache" and exact.n == 2 ** 14
    nearest = cache.get(2 ** 13, 8, 4, "fused", "float32")
    assert nearest.source == "cache-nearest"
    assert nearest.n == 2 ** 14           # the donor shape, log-closest
    # a different backend/dtype never cross-serves
    assert cache.get(2 ** 14, 8, 4, "pallas", "float32") is None
    assert cache.get(2 ** 14, 8, 4, "fused", "bfloat16") is None


def test_backend_key_mesh_routes_to_local():
    assert backend_key(FusedBackend()) == "fused"
    assert record_key(1, 2, 3, "fused", "float32") == \
        "fused|float32|n1|k2|d3"


# ---------------------------------------------------------------------------
# engine integration: off = bitwise, warm cache = zero measurement
# ---------------------------------------------------------------------------

def test_tune_off_is_bitwise_identical():
    pts = _points(n=1024, d=4, k=8)
    key = jax.random.PRNGKey(7)
    base = ClusterEngine("fused")
    off = ClusterEngine("fused", tune="off")
    s0, s1 = base.seed(key, pts, 8), off.seed(key, pts, 8)
    np.testing.assert_array_equal(np.asarray(s0.centroids),
                                  np.asarray(s1.centroids))
    f0 = base.fit(pts, s0.centroids, max_iters=5)
    f1 = off.fit(pts, s1.centroids, max_iters=5)
    np.testing.assert_array_equal(np.asarray(f0.centroids),
                                  np.asarray(f1.centroids))
    np.testing.assert_array_equal(np.asarray(f0.assignment),
                                  np.asarray(f1.assignment))
    assert s1.tune is None and f1.tune is None


def test_warm_cache_zero_measurement_calls(tmp_path):
    pts = _points(n=1024, d=4, k=8)
    key = jax.random.PRNGKey(3)
    warm = ClusterEngine("fused", tune="auto", tune_dir=tmp_path)
    res = warm.seed(key, pts, 8)          # cold: searches and persists
    assert res.tune is not None and res.tune.source in ("model", "measured")

    calls_before = measure.CALLS
    eng = ClusterEngine("fused", tune="cache", tune_dir=tmp_path)
    res2 = eng.seed(key, pts, 8)
    res3 = eng.fit(pts, res2.centroids, max_iters=3)
    assert measure.CALLS == calls_before  # pinned: zero extra measurement
    assert res2.tune.source == "cache"
    assert res3.tune.source in ("cache", "cache-nearest")


def test_tuned_run_is_a_valid_clustering(tmp_path):
    pts = _points(n=2048, d=4, k=8, seed=1)
    key = jax.random.PRNGKey(11)
    tuned = ClusterEngine("fused", tune="auto", tune_dir=tmp_path)
    default = ClusterEngine("fused")
    rt = tuned.kmeans(key, pts, 8, max_iters=8)
    rd = default.kmeans(key, pts, 8, max_iters=8)
    assert rt.tune is not None
    assert rt.tune.block_n > 0 and rt.tune.tps > 0
    # tuned geometry changes reduction trees, not the algorithm: the
    # clusterings agree to fp tolerance
    assert float(rt.inertia) == pytest.approx(float(rd.inertia), rel=1e-4)


def test_tune_cache_mode_cold_is_heuristic(tmp_path):
    calls_before = measure.CALLS
    eng = ClusterEngine("fused", tune="cache", tune_dir=tmp_path)
    res = eng.seed(jax.random.PRNGKey(0), _points(), 8)
    assert res.tune is None               # nothing known, nothing applied
    assert measure.CALLS == calls_before  # ...and nothing measured
    assert not (tmp_path / "tune_cache.json").exists()


def test_resolve_modes(tmp_path):
    cache = TuneCache(tmp_path)
    assert resolve(cache, n=2 ** 14, k=8, d=4, backend="fused",
                   dtype="float32", mode="cache") is None
    rec = resolve(cache, n=2 ** 14, k=8, d=4, backend="fused",
                  dtype="float32", mode="auto")
    assert rec is not None and (tmp_path / "tune_cache.json").exists()
    again = resolve(cache, n=2 ** 14, k=8, d=4, backend="fused",
                    dtype="float32", mode="cache")
    assert again.source == "cache"


def test_search_beats_or_matches_default_model_bytes():
    """The acceptance shape: the swept winner is never worse than the
    heuristic on modelled bytes, and at least one sweep shape strictly
    beats it (the ~sqrt super fan-in leaves accumulator bytes on the
    table)."""
    recs = [search(n, k, d) for n, k, d in
            ((2 ** 16, 16, 8), (2 ** 14, 8, 2), (2 ** 17, 32, 16))]
    assert all(r.predicted_bytes <= r.default_bytes for r in recs)
    assert any(r.predicted_bytes < r.default_bytes for r in recs)


# ---------------------------------------------------------------------------
# parameterized tiles_per_super / block_n plumbing (satellite 1 + 6)
# ---------------------------------------------------------------------------

def test_tiles_per_super_override_semantics():
    # default heuristic preserved bitwise
    assert bounds.tiles_per_super(4) == 1
    assert bounds.tiles_per_super(16) == 4
    assert bounds.tiles_per_super(16, None) == 4
    # override: pow2-floored, clamped to [1, next_pow2(n_tiles)]
    assert bounds.tiles_per_super(16, 8) == 8
    assert bounds.tiles_per_super(16, 7) == 4      # floored to pow2
    assert bounds.tiles_per_super(16, 1000) == 16  # clamped to cap
    assert bounds.tiles_per_super(16, 1) == 1
    assert bounds.n_supers(16, 16) == 1
    assert bounds.n_supers(16, 1) == 16


def test_backend_tps_heuristic_value_is_bitwise():
    """Pinning tps to the heuristic's own value is the SAME geometry, so
    the fit is bitwise the default — the tps plumbing is pure threading."""
    pts = _points(n=4096, d=4, k=8, seed=2)
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(5), pts, 8)
    n_tiles = -(-4096 // FusedBackend().seed_tile(4096, 4, 8))
    tps = bounds.tiles_per_super(n_tiles)
    f0 = ClusterEngine("fused").fit(pts, seeds.centroids, max_iters=4)
    f1 = ClusterEngine("fused", tps=tps).fit(pts, seeds.centroids,
                                             max_iters=4)
    np.testing.assert_array_equal(np.asarray(f0.centroids),
                                  np.asarray(f1.centroids))


def test_backend_tps_changes_fan_in_not_results():
    """A non-default tps changes the accumulator tree only: assignments
    are identical, centroids agree to fp tolerance."""
    pts = _points(n=4096, d=4, k=8, seed=2)
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(5), pts, 8)
    f0 = ClusterEngine("fused").fit(pts, seeds.centroids, max_iters=4)
    f1 = ClusterEngine("fused", tps=1024).fit(pts, seeds.centroids,
                                              max_iters=4)
    np.testing.assert_array_equal(np.asarray(f0.assignment),
                                  np.asarray(f1.assignment))
    np.testing.assert_allclose(np.asarray(f0.centroids),
                               np.asarray(f1.centroids), rtol=1e-5,
                               atol=1e-5)


def test_backend_block_n_only_shrinks_the_pick():
    be = FusedBackend()
    pick = be.seed_tile(2 ** 16, 8, 16)
    assert FusedBackend(block_n=pick * 2).seed_tile(2 ** 16, 8, 16) == pick
    assert FusedBackend(block_n=pick // 2).seed_tile(2 ** 16, 8, 16) \
        == pick // 2
    assert FusedBackend(block_n=1).seed_tile(2 ** 16, 8, 16) == 128
    assert FusedBackend(block_n=0).seed_tile(2 ** 16, 8, 16) == pick


def test_tuned_block_n_runs_end_to_end():
    pts = _points(n=2048, d=4, k=8, seed=3)
    key = jax.random.PRNGKey(9)
    r0 = ClusterEngine("fused").kmeans(key, pts, 8, max_iters=6)
    r1 = ClusterEngine("fused", block_n=256, tps=2).kmeans(key, pts, 8,
                                                           max_iters=6)
    assert float(r1.inertia) == pytest.approx(float(r0.inertia), rel=1e-3)


def test_pick_block_n_uses_shared_budget_table():
    """satellite 6: the implementation sums exactly the shared table."""
    for d, k, bn in ((2, 8, 4096), (64, 256, 1024), (512, 1024, 128)):
        ws = sum(ops.vmem_working_set(d, k, bn).values())
        assert ws == sum(ops.vmem_working_set(d, k, bn).values())
        assert ops.pick_block_n(d, k) >= 128


def test_tune_record_attached_to_batched_results(tmp_path):
    B, n, d, k = 3, 512, 4, 4
    pts = jnp.stack([_points(n=n, d=d, k=k, seed=s) for s in range(B)])
    eng = ClusterEngine("fused", tune="auto", tune_dir=tmp_path)
    res = eng.kmeans_batched(jax.random.PRNGKey(1), pts, k, max_iters=3)
    assert res.tune is not None and res.tune.n == n
