"""Data pipeline: determinism, exact resume, prefetch, semdedup."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data import DataPipeline, TokenStream, blobs, semdedup


def test_token_stream_deterministic():
    s1 = TokenStream(1000, seed=7)
    s2 = TokenStream(1000, seed=7)
    a = s1.read(13, 4, 32)
    b = s2.read(13, 4, 32)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = s1.read(14, 4, 32)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    s = TokenStream(500, seed=0)
    b = s.read(0, 2, 16)
    # labels[t] is the next token of tokens[t] by construction
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_stream_is_learnable():
    """The motif injection must create predictable structure (else the
    end-to-end training example can't show loss decreasing)."""
    s = TokenStream(100, seed=1)
    b = s.read(0, 8, 256)
    # repeated motif => unigram entropy of a row is well below log(vocab)
    row = b["tokens"][0]
    _, counts = np.unique(row, return_counts=True)
    p = counts / counts.sum()
    ent = -(p * np.log(p)).sum()
    assert ent < 0.8 * np.log(100)


def test_pipeline_order_and_resume():
    stream = TokenStream(100, seed=3)
    pipe = DataPipeline(lambda s: stream.read(s, 2, 8), prefetch=2)
    it = iter(pipe)
    got = [next(it)[0] for _ in range(5)]
    assert got == [0, 1, 2, 3, 4]
    pipe.stop()

    pipe2 = DataPipeline(lambda s: stream.read(s, 2, 8), prefetch=2)
    pipe2.skip_to(3)
    it2 = iter(pipe2)
    s, batch = next(it2)
    assert s == 3
    np.testing.assert_array_equal(batch["tokens"],
                                  stream.read(3, 2, 8)["tokens"])
    pipe2.stop()


def test_blobs_shapes_and_labels():
    pts, labels = blobs(1000, 3, 7, seed=0)
    assert pts.shape == (1000, 3) and labels.shape == (1000,)
    assert labels.min() >= 0 and labels.max() < 7


def test_semdedup_drops_duplicates():
    key = jax.random.PRNGKey(0)
    base = jax.random.normal(key, (64, 16))
    # 16 exact duplicates appended
    embeds = jnp.concatenate([base, base[:16] * 1.0001], axis=0)
    res = semdedup(jax.random.PRNGKey(1), embeds, k=4, threshold=0.99)
    assert int(res.n_kept) <= 64 + 2     # dups dropped (cluster-boundary slack)
    # originals (earlier indices) are kept
    assert bool(res.keep_mask[:64].all())


def test_semdedup_keeps_distinct():
    e = jnp.eye(32)                       # orthogonal: nothing near-duplicate
    res = semdedup(jax.random.PRNGKey(0), e, k=4, threshold=0.9)
    assert int(res.n_kept) == 32
