"""Data pipeline: determinism, exact resume, prefetch, semdedup."""
import numpy as np
import jax
import pytest
import jax.numpy as jnp

from repro.data import DataPipeline, TokenStream, blobs, semdedup


def test_token_stream_deterministic():
    s1 = TokenStream(1000, seed=7)
    s2 = TokenStream(1000, seed=7)
    a = s1.read(13, 4, 32)
    b = s2.read(13, 4, 32)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = s1.read(14, 4, 32)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    s = TokenStream(500, seed=0)
    b = s.read(0, 2, 16)
    # labels[t] is the next token of tokens[t] by construction
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_stream_is_learnable():
    """The motif injection must create predictable structure (else the
    end-to-end training example can't show loss decreasing)."""
    s = TokenStream(100, seed=1)
    b = s.read(0, 8, 256)
    # repeated motif => unigram entropy of a row is well below log(vocab)
    row = b["tokens"][0]
    _, counts = np.unique(row, return_counts=True)
    p = counts / counts.sum()
    ent = -(p * np.log(p)).sum()
    assert ent < 0.8 * np.log(100)


def test_pipeline_order_and_resume():
    stream = TokenStream(100, seed=3)
    pipe = DataPipeline(lambda s: stream.read(s, 2, 8), prefetch=2)
    it = iter(pipe)
    got = [next(it)[0] for _ in range(5)]
    assert got == [0, 1, 2, 3, 4]
    pipe.stop()

    pipe2 = DataPipeline(lambda s: stream.read(s, 2, 8), prefetch=2)
    pipe2.skip_to(3)
    it2 = iter(pipe2)
    s, batch = next(it2)
    assert s == 3
    np.testing.assert_array_equal(batch["tokens"],
                                  stream.read(3, 2, 8)["tokens"])
    pipe2.stop()


def test_blobs_shapes_and_labels():
    pts, labels = blobs(1000, 3, 7, seed=0)
    assert pts.shape == (1000, 3) and labels.shape == (1000,)
    assert labels.min() >= 0 and labels.max() < 7


def test_semdedup_drops_duplicates():
    key = jax.random.PRNGKey(0)
    base = jax.random.normal(key, (64, 16))
    # 16 exact duplicates appended
    embeds = jnp.concatenate([base, base[:16] * 1.0001], axis=0)
    res = semdedup(jax.random.PRNGKey(1), embeds, k=4, threshold=0.99)
    assert int(res.n_kept) <= 64 + 2     # dups dropped (cluster-boundary slack)
    # originals (earlier indices) are kept
    assert bool(res.keep_mask[:64].all())


def test_semdedup_keeps_distinct():
    e = jnp.eye(32)                       # orthogonal: nothing near-duplicate
    res = semdedup(jax.random.PRNGKey(0), e, k=4, threshold=0.9)
    assert int(res.n_kept) == 32


# ---------------------------------------------------------------------------
# transient-failure retries (ISSUE 7 satellite)
# ---------------------------------------------------------------------------


def test_pipeline_retries_transient_failures_in_order():
    from repro.testing import flaky_read_fn
    stream = TokenStream(100, seed=9)
    fails = {2: 2}                      # step 2 flakes twice, then succeeds
    pipe = DataPipeline(
        flaky_read_fn(lambda s: stream.read(s, 2, 8), fail_steps=fails),
        prefetch=1, backoff=0.01)
    it = iter(pipe)
    got = [next(it) for _ in range(4)]
    pipe.stop()
    assert [s for s, _ in got] == [0, 1, 2, 3]
    np.testing.assert_array_equal(got[2][1]["tokens"],
                                  stream.read(2, 2, 8)["tokens"])
    assert fails == {2: 0}


def test_pipeline_exhausted_retries_raise_typed_error_with_step():
    from repro.core.guards import PipelineError
    pipe = DataPipeline(lambda s: (_ for _ in ()).throw(IOError("flaky")),
                        prefetch=1, retries=3, backoff=0.005)
    with pytest.raises(PipelineError, match="read_fn failed") as ei:
        next(iter(pipe))
    pipe.stop()
    assert ei.value.step == 0
    assert isinstance(ei.value.__cause__, IOError)


def test_pipeline_backoff_is_bounded_and_deterministic():
    pipe = DataPipeline(lambda s: {}, retries=5, backoff=0.05)
    d1 = [pipe._delay(3, a) for a in range(5)]
    d2 = [pipe._delay(3, a) for a in range(5)]
    assert d1 == d2                      # same (step, attempt) -> same jitter
    assert all(0.0 < d <= 2.0 for d in d1)
    assert d1[1] > d1[0] * 1.2           # exponential growth dominates jitter
    # different steps de-synchronize (fleet doesn't hammer in lockstep)
    assert pipe._delay(4, 0) != pipe._delay(3, 0)
