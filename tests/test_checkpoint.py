"""Checkpoint manager: atomic commit, async save, GC, dtype fidelity."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "b16": jax.random.normal(k, (4,), jnp.bfloat16)},
        "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(7, jnp.int32)},
        "rng": jax.random.PRNGKey(3),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = _state()
    mgr.save(5, state)
    step, got = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 5
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(state)[0],
            jax.tree_util.tree_flatten_with_path(got)[0]):
        assert a.dtype == b.dtype, pa
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    s = _state()
    mgr.save(1, s)
    mgr.save(2, s)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_gc_keeps_newest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    s = _state()
    for step in (1, 2, 3, 4):
        mgr.save(step, s)
    assert mgr.all_steps() == [3, 4]


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs are never listed as restorable steps."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    (tmp_path / "step_00000009.tmp").mkdir()
    s = _state()
    mgr.save(1, s)
    assert mgr.all_steps() == [1]


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=0, async_save=False)
    s1, s2 = _state(1), _state(2)
    mgr.save(1, s1)
    mgr.save(2, s2)
    _, got = mgr.restore(jax.tree.map(jnp.zeros_like, s1), step=1)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(s1["params"]["w"]))


def test_missing_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore({"x": jnp.zeros(())})


# ---------------------------------------------------------------------------
# manifest meta + bound-state geometry stamps (ISSUE 7: checkpointed bound
# state must never restore onto a mismatched shard/tile geometry)
# ---------------------------------------------------------------------------


def test_manifest_meta_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager as M
    mgr = M(tmp_path, async_save=False)
    mgr.save(3, _state(), meta={"kind": "seed", "k": 7})
    man = mgr.read_manifest(3)
    assert man["meta"] == {"kind": "seed", "k": 7}
    assert man["step"] == 3 and "shapes" in man
    # meta-less saves stay readable (back-compat)
    mgr.save(4, _state())
    assert mgr.read_manifest(4).get("meta") is None
    assert mgr.read_manifest()["step"] == 4          # default: latest


def _bound_state(n_tiles, seed=0):
    import jax.numpy as jnp
    from repro.core.bounds import BoundState
    k = jax.random.PRNGKey(seed)
    return BoundState(jax.random.uniform(k, (n_tiles,)),
                      jax.random.uniform(jax.random.fold_in(k, 1),
                                         (n_tiles,)) + 1.0)


@pytest.mark.parametrize("shards", [8, 4, 1])
def test_bound_state_same_geometry_roundtrips_bitwise(tmp_path, shards):
    from repro.checkpoint import restore_bound_state, save_bound_state
    st = _bound_state(128 // max(shards, 1))
    save_bound_state(tmp_path, 1, st, shards=shards, tile=128)
    got = restore_bound_state(tmp_path, jax.tree.map(jnp.zeros_like, st),
                              shards=shards, tile=128)
    assert got is not None
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bound_state_reshard_invalidates(tmp_path):
    """8 -> 4 -> 1 shards: the shard-local tile layout no longer matches, so
    restore returns None and the caller rebuilds with one ungated round —
    never a silently-interleaved (wrong) bound state."""
    from repro.checkpoint import restore_bound_state, save_bound_state
    st = _bound_state(16)
    save_bound_state(tmp_path, 1, st, shards=8, tile=128)
    like = jax.tree.map(jnp.zeros_like, st)
    for shards in (4, 1):
        assert restore_bound_state(tmp_path, like, shards=shards,
                                   tile=128) is None
    # a tile-height change invalidates the same way
    assert restore_bound_state(tmp_path, like, shards=8, tile=256) is None


def test_bound_state_restore_errors_are_typed(tmp_path):
    from repro.checkpoint import restore_bound_state, save_bound_state
    from repro.core.guards import CheckpointError, ClusteringError
    st = _bound_state(8)
    like = jax.tree.map(jnp.zeros_like, st)
    with pytest.raises(CheckpointError, match="no bound-state checkpoint"):
        restore_bound_state(tmp_path / "empty", like, shards=1, tile=128)
    # a foreign (non-bound-state) checkpoint is refused, not misread
    from repro.checkpoint.manager import CheckpointManager as M
    M(tmp_path, async_save=False).save(1, _state(), meta={"kind": "train"})
    with pytest.raises(CheckpointError, match="not a bound-state"):
        restore_bound_state(tmp_path, like, shards=1, tile=128)
    assert issubclass(CheckpointError, ClusteringError)


# ---------------------------------------------------------------------------
# engine-level checkpointed seeding: chunked driver == one-shot, resume
# bitwise, meta compatibility enforced
# ---------------------------------------------------------------------------


def _seed_problem():
    from repro.data.synthetic import blobs
    pts = jnp.asarray(blobs(4096, 2, 6, seed=3, spread=0.05)[0])
    return pts, jax.random.PRNGKey(4)


def test_checkpointed_seed_matches_plain_and_resumes(tmp_path):
    import shutil
    from repro.core.engine import ClusterEngine
    from repro.checkpoint.manager import CheckpointManager as M
    pts, key = _seed_problem()
    eng = ClusterEngine("fused")
    plain = eng.seed(key, pts, 6)
    ck = eng.seed(key, pts, 6, checkpoint_dir=tmp_path, checkpoint_every=2)
    np.testing.assert_array_equal(np.asarray(plain.centroids),
                                  np.asarray(ck.centroids))
    np.testing.assert_array_equal(np.asarray(plain.indices),
                                  np.asarray(ck.indices))
    np.testing.assert_array_equal(np.asarray(plain.min_d2),
                                  np.asarray(ck.min_d2))
    mgr = M(tmp_path)
    assert mgr.latest_step() == 6
    assert mgr.read_manifest()["meta"]["kind"] == "seed"
    # crash simulation: drop the newest checkpoints, rerun -> bitwise
    for step in mgr.all_steps()[-2:]:
        shutil.rmtree(tmp_path / f"step_{step:08d}")
    res = eng.seed(key, pts, 6, checkpoint_dir=tmp_path, checkpoint_every=2)
    np.testing.assert_array_equal(np.asarray(plain.centroids),
                                  np.asarray(res.centroids))
    np.testing.assert_array_equal(np.asarray(plain.min_d2),
                                  np.asarray(res.min_d2))


def test_checkpointed_seed_refuses_mismatched_run(tmp_path):
    from repro.core.engine import ClusterEngine
    from repro.core.guards import CheckpointError
    pts, key = _seed_problem()
    eng = ClusterEngine("fused")
    eng.seed(key, pts, 6, checkpoint_dir=tmp_path, checkpoint_every=2)
    with pytest.raises(CheckpointError, match="meta"):
        eng.seed(key, pts, 5, checkpoint_dir=tmp_path, checkpoint_every=2)


def test_checkpointed_seed_rejects_unsupported_modes(tmp_path):
    from repro.core.engine import ClusterEngine, MeshBackend
    from repro.core.guards import CheckpointError
    pts, key = _seed_problem()
    with pytest.raises(CheckpointError, match="rejection"):
        ClusterEngine("fused").seed(key, pts, 6, sampler="rejection",
                                    checkpoint_dir=tmp_path)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(CheckpointError, match="local"):
        ClusterEngine(MeshBackend(mesh=mesh, axes=("data",))).seed(
            key, pts, 6, checkpoint_dir=tmp_path)
