"""Checkpoint manager: atomic commit, async save, GC, dtype fidelity."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "b16": jax.random.normal(k, (4,), jnp.bfloat16)},
        "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(7, jnp.int32)},
        "rng": jax.random.PRNGKey(3),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = _state()
    mgr.save(5, state)
    step, got = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 5
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(state)[0],
            jax.tree_util.tree_flatten_with_path(got)[0]):
        assert a.dtype == b.dtype, pa
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    s = _state()
    mgr.save(1, s)
    mgr.save(2, s)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_gc_keeps_newest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    s = _state()
    for step in (1, 2, 3, 4):
        mgr.save(step, s)
    assert mgr.all_steps() == [3, 4]


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs are never listed as restorable steps."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    (tmp_path / "step_00000009.tmp").mkdir()
    s = _state()
    mgr.save(1, s)
    assert mgr.all_steps() == [1]


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=0, async_save=False)
    s1, s2 = _state(1), _state(2)
    mgr.save(1, s1)
    mgr.save(2, s2)
    _, got = mgr.restore(jax.tree.map(jnp.zeros_like, s1), step=1)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(s1["params"]["w"]))


def test_missing_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore({"x": jnp.zeros(())})
