"""Hypothesis property tests for the seeding/Lloyd core. Kept in their own
module so the rest of the suite runs when hypothesis is not installed (it is a
dev-only dependency — see requirements-dev.txt / pip install -e .[dev])."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import kmeanspp, sampling
from repro.core.engine import ClusterEngine
from repro.core.lloyd import assign, update


@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 48), block_n=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_property_tiled_two_level_is_distribution_exact(n, block_n, seed):
    """Acceptance (ISSUE 2): the tiled sampler's u -> index map induces the
    same index probabilities as the global inverse-CDF. Enumerated on a dense
    deterministic u-grid, so the u-measure of each index is the sampling
    probability up to grid resolution."""
    rng = np.random.default_rng(seed)
    w = np.abs(rng.normal(size=n)).astype(np.float32)
    w[rng.random(size=n) < 0.2] = 0.0
    if w.sum() == 0:
        w[0] = 1.0
    w = jnp.asarray(w)
    partials = sampling.tile_partials(w, block_n)
    M = 2048
    us = jnp.asarray((np.arange(M) + 0.5) / M, jnp.float32)
    glob = np.asarray(jax.vmap(
        lambda u: sampling.index_from_uniform(u, w))(us))
    tile = np.asarray(jax.vmap(
        lambda u: sampling.tiled_index_from_uniform(
            u, w, partials, block_n=block_n))(us))
    # equal except within fp-ulp of distribution breakpoints
    n_tiles = partials.shape[0]
    assert (glob == tile).mean() >= 1.0 - (n + n_tiles + 2) / M
    probs = np.bincount(tile, minlength=n) / M
    want = np.asarray(w) / float(jnp.sum(w))
    np.testing.assert_allclose(probs, want, atol=3.0 / M * n_tiles + 1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_tiled_seeding_valid(seed):
    """Full k-means++ with sampler='tiled': valid distinct indices, finite
    centroids (mirrors test_property_valid_result for the new sampler)."""
    pts = jax.random.normal(jax.random.PRNGKey(seed), (96, 3))
    res = kmeanspp(jax.random.PRNGKey(seed + 1), pts, 6, sampler="tiled")
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < 96)).all()
    assert len(set(idx.tolist())) == 6
    assert np.isfinite(np.asarray(res.centroids)).all()


@settings(max_examples=8, deadline=None)
@given(backend=st.sampled_from(["reference", "fused", "pallas"]),
       seed=st.integers(0, 2**31 - 1))
def test_property_rejection_accept_path_pins_tiled(backend, seed):
    """ISSUE 6 acceptance: sampler='rejection' with refresh_block=1 (every
    round freshens the envelope, so p == q bitwise and the first proposal
    always accepts) consumes the SAME uniform stream as sampler='tiled' and
    must pick the identical seed indices — across every local backend."""
    pts = jax.random.normal(jax.random.PRNGKey(seed), (192, 4))
    key = jax.random.PRNGKey(seed ^ 0xBEE5)
    eng = ClusterEngine(backend)
    a = eng.seed(key, pts, 7, sampler="tiled")
    b = eng.seed(key, pts, 7, sampler="rejection", refresh_block=1)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    assert np.asarray(b.accepts)[1:].all(), "fresh envelope must accept"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       refresh_block=st.sampled_from([2, 4, 8]))
def test_property_rejection_seeding_valid(seed, refresh_block):
    """Stale-envelope rounds (refresh_block > 1): valid distinct indices,
    finite centroids, and a returned min_d2 that is EXACT over all chosen
    seeds (the loop settles its refresh debt before returning)."""
    pts = jax.random.normal(jax.random.PRNGKey(seed), (160, 3))
    res = ClusterEngine("fused").seed(jax.random.PRNGKey(seed + 1), pts, 6,
                                      sampler="rejection",
                                      refresh_block=refresh_block)
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < 160)).all()
    assert len(set(idx.tolist())) == 6
    d2 = jnp.min(jnp.sum((pts[:, None, :] - res.centroids[None]) ** 2, -1), 1)
    np.testing.assert_allclose(np.asarray(res.min_d2), np.asarray(d2),
                               rtol=2e-4, atol=1e-4)


def test_rejection_batched_pins_tiled_per_problem():
    """The vmapped (batched) path keeps the shared-stream pin: every problem
    in a (B, n, d) batch picks its single-problem rejection == tiled seeds."""
    B = 4
    pts = jax.random.normal(jax.random.PRNGKey(3), (B, 128, 3))
    keys = jax.random.split(jax.random.PRNGKey(4), B)
    eng = ClusterEngine("fused")
    t = eng.seed_batched(keys, pts, 5, sampler="tiled")
    r = eng.seed_batched(keys, pts, 5, sampler="rejection", refresh_block=1)
    np.testing.assert_array_equal(np.asarray(t.indices), np.asarray(r.indices))
    for b in range(B):
        single = eng.seed(keys[b], pts[b], 5, sampler="rejection",
                          refresh_block=1)
        np.testing.assert_array_equal(np.asarray(r.indices[b]),
                                      np.asarray(single.indices))


def test_rejection_matches_tiled_seed_distribution_chi_square():
    """ISSUE 6 acceptance: beyond the shared-key pin, the MARGINAL seed-index
    distribution of sampler='rejection' (stale envelopes, refresh_block=4)
    matches sampler='tiled' — two-sample chi-square over the second seed's
    index across B independent deterministic keys, computed by hand (no scipy
    dependency). Both samplers are exact, so the statistic is ~chi2(df) and a
    loose threshold keeps the test deterministic-and-tight-free of flakes."""
    n, d, k, B = 64, 2, 3, 400
    pts = jax.random.normal(jax.random.PRNGKey(11), (n, d))
    batch = jnp.broadcast_to(pts, (B, n, d))
    keys = jax.random.split(jax.random.PRNGKey(12), B)
    eng = ClusterEngine("fused")
    t = np.asarray(eng.seed_batched(keys, batch, k,
                                    sampler="tiled").indices)
    r = np.asarray(eng.seed_batched(keys, batch, k, sampler="rejection",
                                    refresh_block=4).indices)
    # pool the 2nd seed's index into 16 buckets of 4 rows; two-sample
    # chi-square: sum (c1 - c2)^2 / (c1 + c2) ~ chi2(#buckets - 1)
    bins = 16
    c_t = np.bincount(t[:, 1] // (n // bins), minlength=bins).astype(float)
    c_r = np.bincount(r[:, 1] // (n // bins), minlength=bins).astype(float)
    tot = c_t + c_r
    stat = float(np.sum(np.where(tot > 0, (c_t - c_r) ** 2 /
                                 np.maximum(tot, 1.0), 0.0)))
    # df = 15; P(chi2 > 60) ~ 2e-7 — far past any plausible fp wiggle, but
    # an off-by-one-distribution bug (e.g. biased fallback) blows well past
    assert stat < 60.0, (stat, c_t, c_r)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 96), block_n=st.sampled_from([4, 8, 16]),
       tps=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_property_super_coreset_draw_is_unbiased(n, block_n, tps, seed):
    """ISSUE 9 acceptance: the per-super coreset draw (super-tile weights =
    sums of their tiles' partials, i.e. gathered CDF prefixes) keeps the
    three-level super -> tile -> row draw UNBIASED — for every uniform it
    telescopes to the exact flat inverse-CDF index, so the induced index
    probabilities are w / sum(w) regardless of how the super level carves
    the tiles (tps can exceed n_tiles, divide it, or straddle a ragged
    tail). Zero-mass tiles and supers included."""
    rng = np.random.default_rng(seed)
    w = np.abs(rng.normal(size=n)).astype(np.float32)
    w[rng.random(size=n) < 0.25] = 0.0
    if w.sum() == 0:
        w[0] = 1.0
    w = jnp.asarray(w)
    partials = sampling.tile_partials(w, block_n)
    tcdf = jnp.cumsum(partials)
    scdf = sampling.super_cdf(tcdf, tps)
    M = 2048
    us = jnp.asarray((np.arange(M) + 0.5) / M, jnp.float32)
    flat = np.asarray(jax.vmap(
        lambda u: sampling.tiled_index_from_uniform(
            u, w, partials, block_n=block_n))(us))
    hier = np.asarray(jax.vmap(
        lambda u: sampling.hier_index_from_uniform(
            u, w, partials, tcdf, scdf, block_n=block_n, tps=tps))(us))
    np.testing.assert_array_equal(flat, hier)
    probs = np.bincount(hier, minlength=n) / M
    want = np.asarray(w) / float(jnp.sum(w))
    n_tiles = partials.shape[0]
    np.testing.assert_allclose(probs, want, atol=3.0 / M * n_tiles + 1e-3)


@settings(max_examples=8, deadline=None)
@given(backend=st.sampled_from(["reference", "fused", "pallas"]),
       seed=st.integers(0, 2**31 - 1))
def test_property_hier_proposal_pins_tiled_at_rb1(backend, seed):
    """ISSUE 9 acceptance: proposal='hier' with refresh_block=1 consumes the
    SAME uniform per round as proposal='flat' (no pending centroids at
    proposal time -> every cap is +inf -> the coarse draw telescopes), so
    both pin sampler='tiled' bitwise across every local backend."""
    pts = jax.random.normal(jax.random.PRNGKey(seed), (192, 4))
    key = jax.random.PRNGKey(seed ^ 0xC0FE)
    eng = ClusterEngine(backend)
    a = eng.seed(key, pts, 7, sampler="tiled")
    b = eng.seed(key, pts, 7, sampler="rejection", refresh_block=1,
                 proposal="hier")
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    assert np.asarray(b.accepts)[1:].all(), "fresh envelope must accept"


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 128), d=st.integers(1, 8), k=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_property_valid_result(n, d, k, seed):
    k = min(k, n)
    pts = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    res = kmeanspp(jax.random.PRNGKey(seed + 1), pts, k)
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < n)).all()
    assert np.isfinite(np.asarray(res.centroids)).all()
    md = np.asarray(res.min_d2)
    assert (md >= 0).all() and np.isfinite(md).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_serial_parallel_equal(seed):
    pts = jax.random.normal(jax.random.PRNGKey(seed), (64, 3))
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    a = kmeanspp(key, pts, 5, variant="serial", sampler="cdf")
    b = kmeanspp(key, pts, 5, variant="fused", sampler="cdf")
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_duplicate_points_zero_d2(seed):
    """All-identical points: after the first seed every D^2 is 0 and sampling
    must still terminate with valid indices."""
    pts = jnp.ones((32, 4)) * 3.14
    res = kmeanspp(jax.random.PRNGKey(seed), pts, 4)
    assert np.asarray(res.min_d2).max() < 1e-6
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < 32)).all()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 64), k=st.integers(2, 6), seed=st.integers(0, 10**6))
def test_property_lloyd_never_increases(n, k, seed):
    k = min(k, n)
    pts = jax.random.normal(jax.random.PRNGKey(seed), (n, 2))
    seeds = kmeanspp(jax.random.PRNGKey(seed + 1), pts, k).centroids
    cents = seeds
    prev = np.inf
    for _ in range(4):
        a, m = assign(pts, cents)
        cur = float(jnp.sum(m))
        assert cur <= prev * (1 + 1e-5) + 1e-6
        prev = cur
        cents = update(pts, a, k, prev_centroids=cents)
