"""Hypothesis property tests for the seeding/Lloyd core. Kept in their own
module so the rest of the suite runs when hypothesis is not installed (it is a
dev-only dependency — see requirements-dev.txt / pip install -e .[dev])."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import kmeanspp
from repro.core.lloyd import assign, update


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 128), d=st.integers(1, 8), k=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_property_valid_result(n, d, k, seed):
    k = min(k, n)
    pts = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    res = kmeanspp(jax.random.PRNGKey(seed + 1), pts, k)
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < n)).all()
    assert np.isfinite(np.asarray(res.centroids)).all()
    md = np.asarray(res.min_d2)
    assert (md >= 0).all() and np.isfinite(md).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_serial_parallel_equal(seed):
    pts = jax.random.normal(jax.random.PRNGKey(seed), (64, 3))
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    a = kmeanspp(key, pts, 5, variant="serial", sampler="cdf")
    b = kmeanspp(key, pts, 5, variant="fused", sampler="cdf")
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_duplicate_points_zero_d2(seed):
    """All-identical points: after the first seed every D^2 is 0 and sampling
    must still terminate with valid indices."""
    pts = jnp.ones((32, 4)) * 3.14
    res = kmeanspp(jax.random.PRNGKey(seed), pts, 4)
    assert np.asarray(res.min_d2).max() < 1e-6
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < 32)).all()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 64), k=st.integers(2, 6), seed=st.integers(0, 10**6))
def test_property_lloyd_never_increases(n, k, seed):
    k = min(k, n)
    pts = jax.random.normal(jax.random.PRNGKey(seed), (n, 2))
    seeds = kmeanspp(jax.random.PRNGKey(seed + 1), pts, k).centroids
    cents = seeds
    prev = np.inf
    for _ in range(4):
        a, m = assign(pts, cents)
        cur = float(jnp.sum(m))
        assert cur <= prev * (1 + 1e-5) + 1e-6
        prev = cur
        cents = update(pts, a, k, prev_centroids=cents)
