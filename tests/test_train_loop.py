"""Fault-tolerance tests for the training loop (pure-python harness around
fake train_steps + a real end-to-end resume test on a smoke arch)."""
import os
import signal
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import TokenStream
from repro.launch.step import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, StepStats, train


def _fake_pipeline():
    return DataPipeline(lambda s: {"x": np.full((2,), s, np.float32)},
                        prefetch=1)


def test_loop_runs_and_counts():
    def step(state, batch):
        return state + 1, {"loss": jnp.asarray(1.0), "lr": 0.1}

    state, summary = train(jnp.asarray(0), step, _fake_pipeline(),
                           LoopConfig(total_steps=7, log_every=100),
                           log_fn=lambda s: None)
    assert int(state) == 7 and summary["final_step"] == 7


def test_nan_steps_skipped_then_abort():
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        return state + 1, {"loss": jnp.asarray(float("nan"))}

    import pytest
    with pytest.raises(FloatingPointError):
        train(jnp.asarray(0), step, _fake_pipeline(),
              LoopConfig(total_steps=50, max_nan_steps=3),
              log_fn=lambda s: None)
    assert calls["n"] == 3


def test_nan_update_skipped_state_preserved():
    def step(state, batch):
        # nan keyed on the BATCH (step index), so it happens exactly once
        loss = jnp.where(batch["x"][0] == 2, jnp.nan, 1.0)
        return state + 1, {"loss": loss}

    state, summary = train(jnp.asarray(0), step, _fake_pipeline(),
                           LoopConfig(total_steps=5, max_nan_steps=3),
                           log_fn=lambda s: None)
    # one update skipped -> state advanced only 4 times
    assert int(state) == 4
    assert summary["final_step"] == 5


def test_checkpoint_resume_continues_data(tmp_path):
    seen = []

    def step(state, batch):
        seen.append(int(batch["x"][0]))
        return state + 1, {"loss": jnp.asarray(0.5)}

    ckpt = CheckpointManager(tmp_path, async_save=False)
    st, _ = train(jnp.asarray(0), step, _fake_pipeline(),
                  LoopConfig(total_steps=4, save_every=2),
                  ckpt=ckpt, log_fn=lambda s: None)
    assert ckpt.latest_step() == 4
    # "crash", restart: resumes at step 4, data continues at 4 (no replay)
    st2, summary = train(jnp.asarray(0), step, _fake_pipeline(),
                         LoopConfig(total_steps=7, save_every=100),
                         ckpt=ckpt, log_fn=lambda s: None)
    assert seen == [0, 1, 2, 3, 4, 5, 6]
    assert int(st2) == 7


def test_preemption_signal_saves_and_exits(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)

    def step(state, batch):
        if int(state) == 2:
            os.kill(os.getpid(), signal.SIGTERM)     # simulated preemption
        return state + 1, {"loss": jnp.asarray(1.0)}

    state, summary = train(jnp.asarray(0), step, _fake_pipeline(),
                           LoopConfig(total_steps=100, save_every=1000),
                           ckpt=ckpt, log_fn=lambda s: None)
    assert summary["preempted"]
    assert summary["final_step"] < 100
    assert ckpt.latest_step() == summary["final_step"]


def test_straggler_detection():
    stats = StepStats()
    flags = [stats.update(0.01, k=3.0) for _ in range(30)]
    assert not any(flags)
    assert stats.update(1.0, k=3.0)      # 100x slower step flagged
    assert stats.stragglers == 1


def test_end_to_end_smoke_train_resumes(tmp_path):
    """Real arch + real checkpoints: train 4 steps, restart, reach 8."""
    cfg = get_config("gemma2-2b", smoke=True)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=8)
    stream = TokenStream(cfg.vocab, seed=0)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    ckpt = CheckpointManager(tmp_path, async_save=False)

    def pipe():
        return DataPipeline(lambda s: stream.read(s, 2, 16), prefetch=1)

    state = init_train_state(cfg, jax.random.PRNGKey(0))
    state, s1 = train(state, step_fn, pipe(),
                      LoopConfig(total_steps=4, save_every=4, log_every=100),
                      ckpt=ckpt, log_fn=lambda s: None)
    assert ckpt.latest_step() == 4

    fresh = init_train_state(cfg, jax.random.PRNGKey(0))
    state2, s2 = train(fresh, step_fn, pipe(),
                       LoopConfig(total_steps=8, save_every=100,
                                  log_every=100),
                       ckpt=ckpt, log_fn=lambda s: None)
    assert s2["final_step"] == 8
    assert len(s2["losses"]) == 4        # only steps 4..7 ran after resume
    assert int(state2["opt"].step) == 8
