"""Sampler unit tests: two-level tiled exactness (deterministic u-grid
enumeration — the hypothesis variant lives in test_kmeanspp_properties.py),
degenerate-weight guards, and gumbel_topk validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling


# ---------------------------------------------------------------------------
# two-level tiled sampler: distribution exactness
# ---------------------------------------------------------------------------

def _weights(n, seed, with_zeros=True):
    w = np.abs(np.random.default_rng(seed).normal(size=n)).astype(np.float32)
    if with_zeros:
        w[:: max(n // 5, 1)] = 0.0
    return jnp.asarray(w)


@pytest.mark.parametrize("n,block_n", [(37, 8), (64, 16), (100, 128),
                                       (256, 32), (13, 4)])
def test_tiled_index_matches_global_cdf_on_u_grid(n, block_n):
    """The two-level map u -> index agrees with the global inverse-CDF map
    everywhere except fp boundary cells, so the induced distributions match.
    (block_n > n exercises the degenerate single-tile case.)"""
    w = _weights(n, seed=n)
    partials = sampling.tile_partials(w, block_n)
    M = 4096
    us = jnp.asarray((np.arange(M) + 0.5) / M, jnp.float32)
    glob = jax.vmap(lambda u: sampling.index_from_uniform(u, w))(us)
    tile = jax.vmap(lambda u: sampling.tiled_index_from_uniform(
        u, w, partials, block_n=block_n))(us)
    glob, tile = np.asarray(glob), np.asarray(tile)
    # identical outside fp-boundary cells: allow one cell per breakpoint
    n_tiles = partials.shape[0]
    assert (glob == tile).mean() >= 1.0 - (n + n_tiles + 2) / M
    # induced probabilities (u-measure per index) match the true weights
    probs = np.bincount(tile, minlength=n) / M
    want = np.asarray(w) / float(jnp.sum(w))
    np.testing.assert_allclose(probs, want, atol=2.5 / M * block_n ** 0.5 + 1e-3)


def test_tiled_never_picks_zero_weight_index():
    w = jnp.asarray([0.0, 2.0, 0.0, 1.0, 0.0, 0.0, 3.0, 0.0], jnp.float32)
    partials = sampling.tile_partials(w, 4)
    for s in range(200):
        idx = int(sampling.categorical_tiled(jax.random.PRNGKey(s), w,
                                             partials, block_n=4))
        assert w[idx] > 0, idx


# ---------------------------------------------------------------------------
# degenerate-weight guards (all-zero / NaN mass)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["cdf", "gumbel"])
def test_all_zero_weights_fall_back_to_uniform(method):
    w = jnp.zeros((16,), jnp.float32)
    idx = [int(sampling.categorical(jax.random.PRNGKey(s), w, method=method))
           for s in range(40)]
    assert all(0 <= i < 16 for i in idx)
    # the old behaviour silently pinned to one clipped index; the guard must
    # actually spread the mass
    assert len(set(idx)) > 4, idx


def test_all_zero_weights_tiled_falls_back_to_uniform():
    w = jnp.zeros((32,), jnp.float32)
    partials = sampling.tile_partials(w, 8)
    idx = [int(sampling.categorical_tiled(jax.random.PRNGKey(s), w, partials,
                                          block_n=8)) for s in range(40)]
    assert all(0 <= i < 32 for i in idx)
    assert len(set(idx)) > 4, idx


def test_tile_window_underflow_falls_back_to_uniform_within_tile():
    """A tile whose PARTIAL survived (so the tile can be drawn) but whose
    window total underflows to exact 0 under fp roundoff must spread
    uniformly over the tile — matching categorical's degenerate-weight
    discipline — instead of collapsing every draw onto the clipped last row."""
    w = jnp.zeros((8,), jnp.float32)
    # fabricated stale partials: tile 1 is drawn with certainty, yet its
    # window (rows 4..7) sums to 0 — the underflow the guard covers
    partials = jnp.asarray([0.0, 1e-30], jnp.float32)
    idx = [int(sampling.tiled_index_from_uniform(
        jnp.float32(u), w, partials, block_n=4))
        for u in np.linspace(0.0, 0.999, 40)]
    assert all(4 <= i < 8 for i in idx)
    assert len(set(idx)) == 4, idx  # uniform spread, not the clip corner


def test_tiled_index_healthy_path_unchanged_by_underflow_guard():
    """The guard must not perturb draws whose window total is positive
    (bitwise parity pin against the pre-guard two-level derivation)."""
    w = _weights(64, seed=9, with_zeros=False)
    bn = 16
    partials = sampling.tile_partials(w, bn)
    tcdf = jnp.cumsum(partials)
    for u in np.linspace(0.0, 0.999, 50):
        r = jnp.float32(u) * tcdf[-1]
        t = int(jnp.clip(jnp.searchsorted(tcdf, r, side="right"), 0, 3))
        r_local = r - (tcdf[t - 1] if t > 0 else 0.0)
        lcdf = jnp.cumsum(sampling.tile_window(w, jnp.int32(t), bn))
        li = int(jnp.clip(jnp.searchsorted(lcdf, r_local, side="right"),
                          0, bn - 1))
        got = int(sampling.tiled_index_from_uniform(
            jnp.float32(u), w, partials, block_n=bn))
        assert got == min(t * bn + li, 63), (u, got, t, li)


@pytest.mark.parametrize("method", ["cdf", "gumbel"])
def test_nan_weights_fall_back_to_valid_index(method):
    w = jnp.asarray([1.0, jnp.nan, 2.0, 3.0], jnp.float32)
    idx = int(sampling.categorical(jax.random.PRNGKey(0), w, method=method))
    assert 0 <= idx < 4


def test_nondegenerate_cdf_unchanged_by_guard():
    """The guard must not perturb the healthy path (bitwise parity pin)."""
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    key = jax.random.PRNGKey(7)
    u = jax.random.uniform(key, (), w.dtype)
    want = sampling.index_from_uniform(u, w)
    got = sampling.categorical_cdf(key, w)
    assert int(want) == int(got)


# ---------------------------------------------------------------------------
# gumbel_topk validation
# ---------------------------------------------------------------------------

def test_gumbel_topk_rejects_k_greater_than_n():
    lw = sampling.safe_log(jnp.ones((4,), jnp.float32))
    with pytest.raises(ValueError, match="k <= n"):
        sampling.gumbel_topk(jax.random.PRNGKey(0), lw, 5)
    idx = sampling.gumbel_topk(jax.random.PRNGKey(0), lw, 4)
    assert sorted(np.asarray(idx).tolist()) == [0, 1, 2, 3]


def test_tile_partials_sums_match():
    w = _weights(100, seed=3, with_zeros=False)
    p = sampling.tile_partials(w, 32)
    assert p.shape == (4,)
    np.testing.assert_allclose(float(jnp.sum(p)), float(jnp.sum(w)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p)[0],
                               float(jnp.sum(w[:32])), rtol=1e-6)


# ---------------------------------------------------------------------------
# fp-invalid envelope guard (ISSUE 7): rejection_sample's `valid` gate
# ---------------------------------------------------------------------------


def test_rejection_sample_valid_gate_skips_proposals():
    """valid=False means the dominance precondition is broken: the proposal
    loop must not run at all (attempts 0, accepted False), routing the
    caller to its exact fallback path instead of a silently-biased draw."""
    key = jax.random.key(40)
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    prop = lambda kj: jax.random.randint(kj, (), 0, 4)
    pq = lambda i: (w[i], w[i])               # fresh envelope: p == q
    idx, ok, att = sampling.rejection_sample(
        key, prop, pq, max_attempts=8, valid=jnp.asarray(False))
    assert int(att) == 0 and not bool(ok)


def test_rejection_sample_valid_true_is_bitwise_the_unguarded_path():
    """The healthy path must be bitwise unchanged by the guard: valid=True
    (or omitted) produces the identical (idx, accepted, attempts)."""
    key = jax.random.key(41)
    w = jnp.asarray([0.1, 0.5, 0.2, 3.0, 0.7])
    stale = w * 1.5                           # dominating stale envelope
    prop = lambda kj: jax.random.categorical(kj, jnp.log(stale))
    pq = lambda i: (w[i], stale[i])
    base = sampling.rejection_sample(key, prop, pq, max_attempts=8)
    gated = sampling.rejection_sample(key, prop, pq, max_attempts=8,
                                      valid=jnp.asarray(True))
    for b, g in zip(base, gated):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(g))
    # and under jit with a traced predicate
    jitted = jax.jit(lambda k, v: sampling.rejection_sample(
        k, prop, pq, max_attempts=8, valid=v))(key, jnp.asarray(True))
    for b, g in zip(base, jitted):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(g))


# ---------------------------------------------------------------------------
# coarse-to-fine (super-tile) draw: bitwise pin, tightened exactness,
# super-level degenerate guard (ISSUE 9)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,block_n,tps", [(64, 8, 2), (100, 16, 4),
                                           (256, 16, 4), (37, 8, 1),
                                           (13, 4, 8)])
def test_hier_index_is_bitwise_tiled_on_u_grid(n, block_n, tps):
    """Untightened, the super -> tile -> row draw telescopes BITWISE to the
    flat two-level draw for every u: the gathered super boundaries make the
    coarse search land on exactly t_flat // tps, and the within-super search
    over the flat tcdf window recovers t_flat itself (tps > n_tiles
    exercises the degenerate one-super case)."""
    w = _weights(n, seed=n + 1)
    partials = sampling.tile_partials(w, block_n)
    tcdf = jnp.cumsum(partials)
    scdf = sampling.super_cdf(tcdf, tps)
    M = 2048
    us = jnp.asarray((np.arange(M) + 0.5) / M, jnp.float32)
    flat = jax.vmap(lambda u: sampling.tiled_index_from_uniform(
        u, w, partials, block_n=block_n))(us)
    hier = jax.vmap(lambda u: sampling.hier_index_from_uniform(
        u, w, partials, tcdf, scdf, block_n=block_n, tps=tps))(us)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(hier))


def test_categorical_hier_bitwise_categorical_tiled():
    """Keyed form of the pin: same uniform derivation + same degenerate
    guard, so healthy draws agree bitwise across keys."""
    w = _weights(96, seed=5)
    partials = sampling.tile_partials(w, 16)
    tps = 2
    for s in range(50):
        key = jax.random.PRNGKey(s)
        a = int(sampling.categorical_tiled(key, w, partials, block_n=16))
        b = int(sampling.categorical_hier(key, w, partials, block_n=16,
                                          tps=tps))
        assert a == b, (s, a, b)


def test_hier_capped_draw_matches_capped_distribution():
    """With caps active the draw must be EXACTLY proportional to the capped
    per-row envelope q~_i = min(w_i, cap_t) * ph_t / sum_t(min(w, cap)) —
    the distribution the accept test prices (see engine seed_points pq_fn).
    Enumerate a dense u-grid and compare induced mass to the analytic q~."""
    n, bn, tps = 64, 8, 2
    w = _weights(n, seed=77, with_zeros=False) + 0.1
    n_tiles = n // bn
    partials = sampling.tile_partials(w, bn)
    rng = np.random.default_rng(7)
    cap_np = rng.uniform(0.2, 2.0, size=n_tiles).astype(np.float32)
    cap_np[::3] = np.inf  # a mix of tightened and untouched tiles
    cap = jnp.asarray(cap_np)
    capw = cap * jnp.asarray(bn, jnp.float32)
    ph = jnp.where(capw < partials, capw, partials)
    tight = ph < partials
    tcdf = jnp.cumsum(ph)
    scdf = sampling.super_cdf(tcdf, tps)
    M = 1 << 15
    us = jnp.asarray((np.arange(M) + 0.5) / M, jnp.float32)
    idx = np.asarray(jax.vmap(lambda u: sampling.hier_index_from_uniform(
        u, w, ph, tcdf, scdf, block_n=bn, tps=tps, cap=cap,
        tight=tight))(us))
    # analytic proposal mass: tile drawn ∝ ph_t, row within ∝ min(w, cap_t)
    wn = np.asarray(w).reshape(n_tiles, bn)
    cw = np.minimum(wn, cap_np[:, None])
    q = np.where(np.asarray(tight)[:, None],
                 cw * (np.asarray(ph) / cw.sum(axis=1))[:, None],
                 wn).reshape(n)
    probs = np.bincount(idx, minlength=n) / M
    np.testing.assert_allclose(probs, q / q.sum(), atol=3e-3)


def test_hier_super_guard_all_zero_falls_back_to_uniform():
    """Satellite regression: an all-zero coarse mass must spread the draw
    over ALL supers/tiles/rows instead of pinning to one clipped corner —
    the tile-level underflow discipline lifted one level."""
    n, bn, tps = 32, 4, 2
    w = jnp.zeros((n,), jnp.float32)
    partials = jnp.zeros((n // bn,), jnp.float32)
    tcdf = jnp.cumsum(partials)
    scdf = sampling.super_cdf(tcdf, tps)
    idx = [int(sampling.hier_index_from_uniform(
        jnp.float32(u), w, partials, tcdf, scdf, block_n=bn, tps=tps))
        for u in np.linspace(0.0, 0.999, 64)]
    assert all(0 <= i < n for i in idx)
    # telescoped uniform: every super (and most rows) visited, no pinning
    assert len(set(i // (bn * tps) for i in idx)) == n // (bn * tps), idx
    assert len(set(idx)) > n // 2, idx


def test_hier_super_guard_nan_falls_back_to_uniform():
    n, bn, tps = 32, 4, 2
    w = _weights(n, seed=11)
    partials = jnp.full((n // bn,), jnp.nan, jnp.float32)
    tcdf = jnp.cumsum(partials)
    scdf = sampling.super_cdf(tcdf, tps)
    idx = [int(sampling.hier_index_from_uniform(
        jnp.float32(u), w, partials, tcdf, scdf, block_n=bn, tps=tps))
        for u in np.linspace(0.0, 0.999, 64)]
    assert all(0 <= i < n for i in idx)
    assert len(set(idx)) > n // 2, idx


def test_hier_super_guard_healthy_path_bitwise_unchanged():
    """The guard's fallback index is computed unconditionally but selected
    only on degenerate mass: healthy draws are bitwise the pre-guard
    derivation (same discipline as the tile-level guard pin)."""
    n, bn, tps = 64, 8, 2
    w = _weights(n, seed=21, with_zeros=False)
    partials = sampling.tile_partials(w, bn)
    tcdf = jnp.cumsum(partials)
    scdf = sampling.super_cdf(tcdf, tps)
    for u in np.linspace(0.0, 0.999, 50):
        got = int(sampling.hier_index_from_uniform(
            jnp.float32(u), w, partials, tcdf, scdf, block_n=bn, tps=tps))
        want = int(sampling.tiled_index_from_uniform(
            jnp.float32(u), w, partials, block_n=bn))
        assert got == want, (u, got, want)


def test_super_cdf_boundaries_are_gathered_prefixes():
    partials = _weights(16, seed=30, with_zeros=False)
    tcdf = jnp.cumsum(partials)
    for tps in (1, 2, 4, 8, 16, 32):
        scdf = sampling.super_cdf(tcdf, tps)
        n_super = -(-16 // tps)
        assert scdf.shape == (n_super,)
        # last boundary is bitwise the flat total (gathered, not re-summed)
        assert float(scdf[-1]) == float(tcdf[-1])
        for s in range(n_super):
            end = min((s + 1) * tps - 1, 15)
            assert float(scdf[s]) == float(tcdf[end])
