"""ClusterEngine seam tests: backend parity (bitwise-identical seeds),
weighted seeding, empty-cluster fallback, mini-batch convergence, and batched
multi-problem clustering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quality
from repro.core.engine import (ClusterEngine, FusedBackend, MeshBackend,
                               PallasBackend, ReferenceBackend, make_backend)
from repro.core.lloyd import assign, update
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import blobs


def _points(n=512, d=2, k=8, seed=0):
    pts, _ = blobs(n, d, k, seed=seed)
    return jnp.asarray(pts)


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

def test_make_backend_names():
    assert isinstance(make_backend("reference"), ReferenceBackend)
    assert make_backend("serial").mode == "serial"
    assert make_backend("global").mode == "global"
    assert isinstance(make_backend("fused"), FusedBackend)
    assert make_backend("pallas").resident
    assert not make_backend("pallas_fused").resident
    b = make_backend("fused")
    assert make_backend(b) is b
    with pytest.raises(ValueError):
        make_backend("cuda")
    with pytest.raises(ValueError):
        make_backend("mesh")  # needs mesh=


# ---------------------------------------------------------------------------
# acceptance: same key => bitwise-identical seeds across backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
def test_seed_parity_across_backends(backend):
    pts = _points(n=512, k=8)
    key = jax.random.PRNGKey(42)
    ref = ClusterEngine("reference", mode="serial").seed(key, pts, 10)
    got = ClusterEngine(backend).seed(key, pts, 10)
    np.testing.assert_array_equal(np.asarray(ref.indices),
                                  np.asarray(got.indices))
    np.testing.assert_array_equal(np.asarray(ref.centroids),
                                  np.asarray(got.centroids))


def test_shims_route_through_engine():
    """The historical kmeanspp(variant=...) entry picks the same seeds as the
    engine with the mapped backend."""
    from repro.core import kmeanspp
    pts = _points(n=300, d=3)
    key = jax.random.PRNGKey(7)
    for variant, backend in (("serial", ReferenceBackend(mode="serial")),
                             ("fused", FusedBackend()),
                             ("pallas_constant", PallasBackend(resident=True))):
        a = kmeanspp(key, pts, 6, variant=variant)
        b = ClusterEngine(backend).seed(key, pts, 6)
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))


# ---------------------------------------------------------------------------
# weighted seeding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
def test_weighted_seeding_respects_zero_weights(backend):
    pts = _points(n=256, d=2, k=4, seed=2)
    w = jnp.where(jnp.arange(256) < 128, 1.0, 0.0)
    res = ClusterEngine(backend).seed(jax.random.PRNGKey(0), pts, 6, weights=w)
    idx = np.asarray(res.indices)
    assert (idx < 128).all(), f"zero-weight point chosen as seed: {idx}"


def test_weighted_seeding_parity_across_backends():
    pts = _points(n=256, d=2, k=4, seed=3)
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (256,))) + 0.1
    key = jax.random.PRNGKey(1)
    ref = ClusterEngine("reference").seed(key, pts, 5, weights=w)
    for backend in ("fused", "pallas"):
        got = ClusterEngine(backend).seed(key, pts, 5, weights=w)
        np.testing.assert_array_equal(np.asarray(ref.indices),
                                      np.asarray(got.indices))


# ---------------------------------------------------------------------------
# Lloyd through the engine + empty-cluster fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
def test_fit_matches_reference_inertia(backend):
    pts = _points(n=600, d=3, k=6)
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(0), pts, 6).centroids
    ref = ClusterEngine("reference").fit(pts, seeds, max_iters=10)
    got = ClusterEngine(backend).fit(pts, seeds, max_iters=10)
    np.testing.assert_allclose(float(got.inertia), float(ref.inertia),
                               rtol=1e-5)


def test_empty_cluster_keeps_prev_centroid_in_update():
    pts = jnp.asarray([[0.0, 0.0], [1.0, 1.0], [1.1, 1.0]])
    cents = jnp.asarray([[0.0, 0.0], [1.0, 1.0], [99.0, 99.0]])
    a, _ = assign(pts, cents)
    new = update(pts, a, 3, prev_centroids=cents)
    np.testing.assert_allclose(np.asarray(new)[2], [99.0, 99.0])


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_empty_cluster_fallback_in_engine_fit(backend):
    pts = jnp.asarray([[0.0, 0.0], [0.1, 0.0], [1.0, 1.0], [1.1, 1.0]])
    cents = jnp.asarray([[0.0, 0.0], [1.0, 1.0], [99.0, 99.0]])
    res = ClusterEngine(backend).fit(pts, cents, max_iters=3)
    # the far centroid owns no points and must survive every iteration
    np.testing.assert_allclose(np.asarray(res.centroids)[2], [99.0, 99.0])


def test_weighted_fit_pulls_centroid_to_heavy_points():
    pts = jnp.asarray([[0.0, 0.0], [1.0, 0.0]])
    w = jnp.asarray([3.0, 1.0])
    res = ClusterEngine("fused").fit(pts, jnp.asarray([[0.4, 0.0]]),
                                     max_iters=2, weights=w)
    np.testing.assert_allclose(np.asarray(res.centroids)[0, 0], 0.25,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# mini-batch Lloyd
# ---------------------------------------------------------------------------

def _mb_setup(n=8192, d=2, k=8, batch=512, seed=1):
    full = jnp.asarray(blobs(n, d, k, seed=seed)[0])
    np_pts = np.asarray(full)

    def read_fn(step):
        lo = (step * batch) % n
        return np_pts[lo:lo + batch]

    return full, read_fn


def test_minibatch_converges_to_full_batch_quality():
    full, read_fn = _mb_setup()
    eng = ClusterEngine("fused")
    seeds = eng.seed(jax.random.PRNGKey(1), full[:512], 8).centroids
    mb = eng.fit_minibatch(seeds, read_fn, n_batches=32)
    assert int(mb.n_iters) == 32
    phi_mb = float(quality.inertia(full, mb.centroids))
    phi_full = float(eng.fit(full, seeds, max_iters=30).inertia)
    assert phi_mb < 1.5 * phi_full, (phi_mb, phi_full)


def test_minibatch_accepts_pipeline_and_early_stops():
    full, read_fn = _mb_setup()
    eng = ClusterEngine("fused")
    seeds = eng.seed(jax.random.PRNGKey(1), full[:512], 8).centroids
    pipe = DataPipeline(read_fn)
    mb = eng.fit_minibatch(seeds, pipe, n_batches=64, tol=1e-3, patience=3)
    assert 0 < int(mb.n_iters) <= 64
    # a well-separated blob problem plateaus long before 64 batches
    assert int(mb.n_iters) < 64
    assert mb.assignment.shape == (512,)


def test_minibatch_rejects_empty_source():
    eng = ClusterEngine("fused")
    with pytest.raises(ValueError):
        eng.fit_minibatch(jnp.zeros((2, 2)), [])


def test_minibatch_requires_count_for_infinite_sources():
    """read_fn and DataPipeline sources stream forever — without n_batches
    the loop would never terminate, so both must raise up front."""
    eng = ClusterEngine("fused")
    read_fn = lambda step: np.zeros((4, 2), np.float32)
    with pytest.raises(ValueError, match="n_batches"):
        eng.fit_minibatch(jnp.zeros((2, 2)), read_fn)
    with pytest.raises(ValueError, match="n_batches"):
        eng.fit_minibatch(jnp.zeros((2, 2)), DataPipeline(read_fn))


def test_minibatch_propagates_read_fn_failure():
    """A dying prefetch thread must raise, not deadlock the consumer."""
    def bad_read(step):
        raise IOError(f"shard {step} missing")

    eng = ClusterEngine("fused")
    with pytest.raises(RuntimeError, match="read_fn failed"):
        eng.fit_minibatch(jnp.zeros((2, 2)), bad_read, n_batches=4)


def test_assign_use_pallas_returns_pair():
    pts = _points(n=200, d=3)
    cents = pts[:4]
    a, md = assign(pts, cents, use_pallas=True)
    a2, md2 = assign(pts, cents)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(md), np.asarray(md2),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# batched multi-problem clustering
# ---------------------------------------------------------------------------

def test_seed_batched_matches_per_problem():
    B = 3
    bpts = jnp.stack([_points(n=256, d=2, k=4, seed=s) for s in range(B)])
    eng = ClusterEngine("fused")
    keys = jax.random.split(jax.random.PRNGKey(3), B)
    batched = eng.seed_batched(keys, bpts, 5)
    assert batched.centroids.shape == (B, 5, 2)
    for b in range(B):
        single = eng.seed(keys[b], bpts[b], 5)
        np.testing.assert_array_equal(np.asarray(batched.indices[b]),
                                      np.asarray(single.indices))


def test_kmeans_batched_end_to_end():
    B, n, k = 4, 1024, 6
    bpts = jnp.stack([_points(n=n, d=2, k=k, seed=10 + s) for s in range(B)])
    out = ClusterEngine("fused").kmeans_batched(jax.random.PRNGKey(2), bpts, k,
                                                max_iters=25)
    assert out.centroids.shape == (B, k, 2)
    assert out.inertia.shape == (B,)
    for b in range(B):
        # every problem must reach blob-quality inertia (spread 0.05, d=2)
        assert float(out.inertia[b]) / n < 3 * 2 * 0.05 ** 2, b


def test_seed_batched_pallas_matches_fused_and_single():
    """The batch-grid pallas kernel path picks the same seeds as the fused
    vmap path AND as B single-problem pallas calls (no fallback, no drift)."""
    B = 3
    bpts = jnp.stack([_points(n=300, d=3, k=4, seed=20 + s) for s in range(B)])
    keys = jax.random.split(jax.random.PRNGKey(9), B)
    pal = ClusterEngine("pallas").seed_batched(keys, bpts, 5)
    fus = ClusterEngine("fused").seed_batched(keys, bpts, 5)
    np.testing.assert_array_equal(np.asarray(pal.indices),
                                  np.asarray(fus.indices))
    for b in range(B):
        single = ClusterEngine("pallas").seed(keys[b], bpts[b], 5)
        np.testing.assert_array_equal(np.asarray(pal.indices[b]),
                                      np.asarray(single.indices))


def test_seed_batched_pallas_tiled_sampler():
    B = 2
    bpts = jnp.stack([_points(n=256, d=2, k=4, seed=30 + s) for s in range(B)])
    keys = jax.random.split(jax.random.PRNGKey(12), B)
    out = ClusterEngine("pallas").seed_batched(keys, bpts, 4, sampler="tiled")
    idx = np.asarray(out.indices)
    assert ((0 <= idx) & (idx < 256)).all()
    for b in range(B):
        assert len(set(idx[b].tolist())) == 4, idx[b]


def test_kmeans_batched_pallas_end_to_end():
    """Acceptance: kmeans_batched on the pallas backend (batch-grid kernels)
    reaches the same inertia as the fused path on every problem."""
    B, n, k = 3, 512, 4
    bpts = jnp.stack([_points(n=n, d=2, k=k, seed=40 + s) for s in range(B)])
    key = jax.random.PRNGKey(5)
    pal = ClusterEngine("pallas").kmeans_batched(key, bpts, k, max_iters=15)
    fus = ClusterEngine("fused").kmeans_batched(key, bpts, k, max_iters=15)
    assert pal.centroids.shape == (B, k, 2)
    np.testing.assert_allclose(np.asarray(pal.inertia),
                               np.asarray(fus.inertia), rtol=1e-4)


def test_batched_rejects_mesh_backend():
    mesh = jax.make_mesh((1,), ("data",))
    eng = ClusterEngine(MeshBackend(mesh=mesh, axes=("data",)))
    with pytest.raises(NotImplementedError):
        eng.seed_batched(jax.random.PRNGKey(0), jnp.zeros((2, 8, 2)), 2)


# ---------------------------------------------------------------------------
# two-level tiled sampling (ISSUE 2 tentpole)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
def test_tiled_sampler_seeds_are_valid_and_distinct(backend):
    pts = _points(n=512, k=8)
    res = ClusterEngine(backend).seed(jax.random.PRNGKey(4), pts, 10,
                                      sampler="tiled")
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < 512)).all()
    assert len(set(idx.tolist())) == 10, idx
    assert np.isfinite(np.asarray(res.centroids)).all()


def test_tiled_sampler_parity_fused_vs_pallas():
    """Fused and pallas backends produce per-tile partials with the same tile
    height and the same per-tile sums, so the two-level draw picks the same
    seeds under one key."""
    pts = _points(n=700, d=3, k=6, seed=5)
    key = jax.random.PRNGKey(11)
    a = ClusterEngine("fused").seed(key, pts, 7, sampler="tiled")
    b = ClusterEngine("pallas").seed(key, pts, 7, sampler="tiled")
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))


def test_tiled_sampler_quality_matches_cdf():
    """Same-distribution claim at the phi level: tiled seeding's potential is
    within the usual k-means++ run-to-run band of cdf seeding."""
    pts = _points(n=4096, d=2, k=16, seed=6)
    eng = ClusterEngine("fused")
    phis = {}
    for sampler in ("cdf", "tiled"):
        phi = [float(quality.inertia(
            pts, eng.seed(jax.random.PRNGKey(s), pts, 16,
                          sampler=sampler).centroids)) for s in range(3)]
        phis[sampler] = sum(phi) / len(phi)
    assert phis["tiled"] < 2.5 * phis["cdf"], phis
    assert phis["cdf"] < 2.5 * phis["tiled"], phis


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                    v, is_leaf=lambda x: isinstance(
                        x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    yield from _iter_eqns(sub.jaxpr)
                elif isinstance(sub, jax.core.Jaxpr):
                    yield from _iter_eqns(sub)


def _cumsum_operand_sizes(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    sizes = set()
    for eqn in _iter_eqns(jaxpr.jaxpr):
        if "cumsum" in eqn.primitive.name:
            sizes.add(eqn.invars[0].aval.shape)
    return sizes

def test_tiled_sampler_has_no_full_n_cumsum_in_jaxpr():
    """Acceptance: with sampler='tiled' the post-kernel sampling reads
    O(n/bn + bn) elements — the traced program must contain no cumsum over
    the full (n,) array, only the (n_tiles,) and (block_n,) scans. The cdf
    sampler is the control: it must show the full-n cumsum."""
    from repro.core import engine as eng_mod
    n = 16384
    pts = jnp.zeros((n, 2), jnp.float32)
    key = jax.random.PRNGKey(0)
    backend = FusedBackend()
    tile = backend.seed_tile(n, 2)
    assert tile < n, "probe must span multiple tiles"

    def seed_with(sampler):
        return lambda k, p: eng_mod.seed_points(k, p, 4, None, backend,
                                                sampler)

    tiled_sizes = _cumsum_operand_sizes(seed_with("tiled"), key, pts)
    assert (n,) not in tiled_sizes, tiled_sizes
    assert tiled_sizes <= {(n // tile,), (tile,)}, tiled_sizes

    cdf_sizes = _cumsum_operand_sizes(seed_with("cdf"), key, pts)
    assert (n,) in cdf_sizes, cdf_sizes


# ---------------------------------------------------------------------------
# empty-cluster reseeding (split the largest cluster)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_empty_reseed_revives_dead_centroid(backend):
    pts = jnp.asarray([[0.0, 0.0], [0.1, 0.0], [1.0, 1.0], [1.1, 1.0]])
    cents = jnp.asarray([[0.0, 0.0], [1.0, 1.0], [99.0, 99.0]])
    keep = ClusterEngine(backend).fit(pts, cents, max_iters=5)
    res = ClusterEngine(backend).fit(pts, cents, max_iters=5, empty="reseed")
    # keep-policy leaves the far centroid dead; reseed must pull it back in
    np.testing.assert_allclose(np.asarray(keep.centroids)[2], [99.0, 99.0])
    assert np.abs(np.asarray(res.centroids)[2]).max() < 50.0
    assert float(res.inertia) < float(keep.inertia)
    # every cluster owns at least one point after reseeding
    assert len(set(np.asarray(res.assignment).tolist())) == 3


def test_empty_reseed_noop_when_no_empty_clusters():
    pts = _points(n=400, d=2, k=4, seed=8)
    seeds = ClusterEngine("fused").seed(jax.random.PRNGKey(0), pts,
                                        4).centroids
    a = ClusterEngine("fused").fit(pts, seeds, max_iters=10)
    b = ClusterEngine("fused").fit(pts, seeds, max_iters=10, empty="reseed")
    np.testing.assert_allclose(np.asarray(a.centroids),
                               np.asarray(b.centroids), rtol=1e-6)


def test_fit_rejects_unknown_empty_policy():
    with pytest.raises(ValueError, match="empty-cluster"):
        ClusterEngine("fused").fit(jnp.zeros((4, 2)), jnp.zeros((2, 2)),
                                   empty="explode")


# ---------------------------------------------------------------------------
# precision & bounds (ISSUE 3 tentpole): norm caching, bf16 streaming,
# exact tile skipping
# ---------------------------------------------------------------------------

def _coherent_points(n=16384, d=2, k=4, seed=0):
    """Blob data sorted by label: tiles become spatially coherent (roughly
    one blob per 4096-point tile at the defaults), which is what makes
    block-level pruning fire (Capó et al.)."""
    pts, labels = blobs(n, d, k, seed=seed)
    return jnp.asarray(pts[np.argsort(labels, kind="stable")])


@pytest.mark.parametrize("backend", ["reference", "fused", "pallas"])
def test_bound_gating_is_bitwise_exact(backend):
    """Acceptance: the fp32 + bounds path is bitwise identical to the ungated
    path — same seeds, same min_d2 — while actually skipping tiles."""
    pts = _coherent_points()
    key = jax.random.PRNGKey(3)
    on = ClusterEngine(backend).seed(key, pts, 12)
    off = ClusterEngine(backend, bounds=False).seed(key, pts, 12)
    np.testing.assert_array_equal(np.asarray(on.indices),
                                  np.asarray(off.indices))
    np.testing.assert_array_equal(np.asarray(on.min_d2),
                                  np.asarray(off.min_d2))
    np.testing.assert_array_equal(np.asarray(on.centroids),
                                  np.asarray(off.centroids))
    assert off.skipped is None
    assert on.skipped is not None and on.skipped.shape == (12,)
    # reference here is mode='global', which gates via the pure-JAX model —
    # it must actually skip, like fused/pallas
    assert int(jnp.sum(on.skipped)) > 0, np.asarray(on.skipped)


def test_serial_reference_never_skips():
    """mode='serial' is the paper's CPU baseline: it carries the bound-state
    contract but never gates (skipped stays 0 every round)."""
    pts = _coherent_points()
    res = ClusterEngine("reference", mode="serial").seed(jax.random.PRNGKey(3),
                                                         pts, 6)
    assert res.skipped is not None
    np.testing.assert_array_equal(np.asarray(res.skipped), np.zeros(6))


@pytest.mark.parametrize("offset", [100.0, -3000.0])
def test_bound_gating_exact_far_from_origin(offset):
    """The skip margin must be ABSOLUTE in the operand magnitude: the matmul
    form's fp32 cancellation error grows with ||x||^2, so off-origin data is
    where a relative-only slack would silently break bitwise exactness."""
    pts = _coherent_points(seed=13) + offset
    key = jax.random.PRNGKey(14)
    for backend in ("fused", "pallas"):
        on = ClusterEngine(backend).seed(key, pts, 10)
        off = ClusterEngine(backend, bounds=False).seed(key, pts, 10)
        np.testing.assert_array_equal(np.asarray(on.indices),
                                      np.asarray(off.indices))
        np.testing.assert_array_equal(np.asarray(on.min_d2),
                                      np.asarray(off.min_d2))


def test_bound_gating_skip_counts_agree_fused_vs_pallas():
    """Both gated implementations (pure-JAX model vs compacted kernel) see
    the same bound decisions on the same data."""
    pts = _coherent_points(seed=4)
    key = jax.random.PRNGKey(5)
    f = ClusterEngine("fused").seed(key, pts, 10)
    p = ClusterEngine("pallas").seed(key, pts, 10)
    np.testing.assert_array_equal(np.asarray(f.indices),
                                  np.asarray(p.indices))
    # the two prologues' tile geometry is only ulp-equal, so a bound sitting
    # exactly on the threshold may flip one tile's decision: counts must
    # agree to +-1 tile per round (results stay bitwise identical either
    # way — skipping is exact)
    np.testing.assert_allclose(np.asarray(f.skipped),
                               np.asarray(p.skipped), atol=1)
    assert int(jnp.sum(f.skipped)) > 0


def test_bound_gating_with_tiled_sampler_and_batched():
    """Tile skipping composes with the tiled sampler (skipped tiles reuse
    their prior partials) and with the batch-grid path."""
    pts = _coherent_points(seed=6)
    key = jax.random.PRNGKey(7)
    for backend in ("fused", "pallas"):
        on = ClusterEngine(backend).seed(key, pts, 8, sampler="tiled")
        off = ClusterEngine(backend, bounds=False).seed(key, pts, 8,
                                                        sampler="tiled")
        np.testing.assert_array_equal(np.asarray(on.indices),
                                      np.asarray(off.indices))
    bpts = jnp.stack([_coherent_points(n=4096, seed=s) for s in (8, 9)])
    keys = jax.random.split(jax.random.PRNGKey(10), 2)
    bat_p = ClusterEngine("pallas").seed_batched(keys, bpts, 6)
    bat_f = ClusterEngine("fused").seed_batched(keys, bpts, 6)
    np.testing.assert_array_equal(np.asarray(bat_p.indices),
                                  np.asarray(bat_f.indices))
    assert bat_p.skipped.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(bat_p.skipped),
                                  np.asarray(bat_f.skipped))


def test_mesh_backend_composes_skip_counters():
    """The mesh path psums the per-shard skipped-tile counts (pod-wide
    counter) and stays gated end to end."""
    mesh = jax.make_mesh((1,), ("data",))
    pts = _coherent_points(n=4096, seed=11)
    eng = ClusterEngine(MeshBackend(mesh=mesh, axes=("data",)))
    res = eng.seed(jax.random.PRNGKey(1), pts, 8)
    assert res.skipped is not None and res.skipped.shape == (8,)
    local = ClusterEngine("fused", bounds=False).seed(jax.random.PRNGKey(1),
                                                      pts, 8)
    assert np.isfinite(np.asarray(res.centroids)).all()
    # same data, same tile geometry: the mesh run's total potential matches
    # the ungated local run's at the same quality level (different sampler)
    assert float(quality.inertia(pts, res.centroids)) < \
        5 * float(quality.inertia(pts, local.centroids))


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_bf16_streaming_quality_parity(backend):
    """precision='bf16' halves the streamed bytes; seeds stay valid (taken
    from the full-precision points) and the Lloyd inertia lands within a
    few percent of fp32 on the paper's blob config."""
    pts = _points(n=4096, d=2, k=16, seed=12)
    key = jax.random.PRNGKey(2)
    f32 = ClusterEngine(backend)
    b16 = ClusterEngine(backend, precision="bf16")
    s32 = f32.seed(key, pts, 16)
    s16 = b16.seed(key, pts, 16)
    idx = np.asarray(s16.indices)
    assert ((0 <= idx) & (idx < 4096)).all()
    # seed centroids are gathered from the fp32 array even when streaming bf16
    np.testing.assert_array_equal(
        np.asarray(s16.centroids),
        np.asarray(pts[jnp.asarray(idx)]))
    phi32 = float(f32.fit(pts, s32.centroids, max_iters=25).inertia)
    phi16 = float(b16.fit(pts, s32.centroids, max_iters=25).inertia)
    assert abs(phi16 - phi32) / phi32 < 0.15, (phi16, phi32)


def test_bf16_fit_streams_bf16_points():
    """The bf16 fit must actually stream bf16 tiles: its jaxpr carries a
    bf16 (n, d) operand into the while-loop body."""
    from repro.core import engine as eng_mod
    pts = jnp.zeros((512, 4), jnp.float32)
    cents = jnp.zeros((4, 4), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda p, c: eng_mod.fit_points(p, c, None, FusedBackend(), 5, 1e-6,
                                        "keep", "bf16"))(pts, cents)
    assert "bf16" in str(jaxpr.jaxpr)


# ---------------------------------------------------------------------------
# norms computed once per call, not once per round (jaxpr pin)
# ---------------------------------------------------------------------------

def _point_norm_reductions(jaxpr, n, d):
    """reduce_sum eqns that look like a ||x||^2 row-norm over point rows:
    2-D operand with trailing dim d and a leading dim much larger than k /
    n_tiles — catches both the full (n, d) jnp form and the Pallas kernels'
    per-tile (block_n, d) form."""
    out = []
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name != "reduce_sum":
            continue
        shape = eqn.invars[0].aval.shape
        if len(shape) == 2 and shape[1] == d and shape[0] >= 1024:
            out.append(shape)
    return out


def _loop_bodies(jaxpr):
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name in ("while", "scan"):
            for v in eqn.params.values():
                for sub in jax.tree_util.tree_leaves(
                        v, is_leaf=lambda x: isinstance(
                            x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        yield sub.jaxpr
                    elif isinstance(sub, jax.core.Jaxpr):
                        yield sub


@pytest.mark.parametrize("backend", [FusedBackend(), PallasBackend()])
def test_seed_computes_point_norms_once_per_call(backend):
    """Acceptance: ||x||^2 appears in the seed jaxpr OUTSIDE the round loop
    (the prologue) and never inside the loop body — norm caching drops d
    FLOPs/point/round."""
    from repro.core import engine as eng_mod
    n, d = 16384, 2
    pts = jnp.zeros((n, d), jnp.float32)
    key = jax.random.PRNGKey(0)
    jaxpr = jax.make_jaxpr(
        lambda kk, pp: eng_mod.seed_points(kk, pp, 4, None, backend))(key,
                                                                     pts)
    assert _point_norm_reductions(jaxpr.jaxpr, n, d), \
        "prologue must compute the row norms"
    for body in _loop_bodies(jaxpr.jaxpr):
        assert not _point_norm_reductions(body, n, d), \
            "round loop must NOT recompute ||x||^2"


@pytest.mark.parametrize("backend", [FusedBackend(), PallasBackend()])
def test_fit_computes_point_norms_once_per_call(backend):
    from repro.core import engine as eng_mod
    n, d = 16384, 2
    pts = jnp.zeros((n, d), jnp.float32)
    cents = jnp.zeros((8, d), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda pp, cc: eng_mod.fit_points(pp, cc, None, backend, 5, 1e-6))(
        pts, cents)
    assert _point_norm_reductions(jaxpr.jaxpr, n, d)
    for body in _loop_bodies(jaxpr.jaxpr):
        assert not _point_norm_reductions(body, n, d), \
            "Lloyd loop must NOT recompute ||x||^2"


def test_kmeans_computes_point_norms_exactly_once():
    """Acceptance (ISSUE 5 satellite): ``kmeans`` runs ONE prologue shared
    by the seed and fit phases — the traced program contains EXACTLY one
    row-norm reduction over the (n, d) points (it used to contain two, one
    per phase), and none inside any loop body."""
    from repro.core import engine as eng_mod
    n, d, k = 16384, 2, 4
    pts = jnp.zeros((n, d), jnp.float32)
    key = jax.random.PRNGKey(0)
    jaxpr = jax.make_jaxpr(
        lambda kk, pp: eng_mod.kmeans_points(kk, pp, k, None,
                                             FusedBackend()))(key, pts)
    norms = _point_norm_reductions(jaxpr.jaxpr, n, d)
    assert len(norms) == 1, norms
    for body in _loop_bodies(jaxpr.jaxpr):
        assert not _point_norm_reductions(body, n, d), \
            "no kmeans loop may recompute ||x||^2"


def test_kmeans_shared_prologue_matches_two_phase_quality():
    """The fused one-prologue kmeans must cluster exactly as well as the
    historical seed-then-fit composition (same seeds under the cdf sampler:
    min_d2 is tile-independent, so the draw is identical)."""
    pts = _points(n=4096, d=2, k=8, seed=21)
    key = jax.random.PRNGKey(22)
    eng = ClusterEngine("fused")
    res = eng.kmeans(key, pts, 8, max_iters=15)
    seeds = eng.seed(key, pts, 8).centroids
    two = eng.fit(pts, seeds, max_iters=15)
    np.testing.assert_array_equal(np.asarray(res.centroids),
                                  np.asarray(two.centroids))
    assert float(res.inertia) == float(two.inertia)


def test_seed_reports_per_point_prune_telemetry():
    """KmeansppResult.pruned: > 0 on coherent data, identical between the
    pure-JAX model and the Pallas kernel, absent when gating is off."""
    pts = _coherent_points(seed=20)
    key = jax.random.PRNGKey(21)
    f = ClusterEngine("fused").seed(key, pts, 10)
    p = ClusterEngine("pallas").seed(key, pts, 10)
    assert f.pruned is not None and f.pruned.shape == (10,)
    assert int(jnp.sum(f.pruned)) > 0, np.asarray(f.pruned)
    np.testing.assert_allclose(np.asarray(f.pruned), np.asarray(p.pruned),
                               atol=2)
    off = ClusterEngine("fused", bounds=False).seed(key, pts, 10)
    assert off.pruned is None


# ---------------------------------------------------------------------------
# kernel block-size selection (satellite: pick_block_n call-site clamp)
# ---------------------------------------------------------------------------

def test_choose_block_n_never_exceeds_point_count():
    from repro.kernels.ops import choose_block_n, pick_block_n
    assert pick_block_n(2, 8) == 4096         # unchanged VMEM-budget picker
    assert choose_block_n(300, 2, 8) == 256   # clamped DOWN below n
    assert choose_block_n(4096, 2, 8) == 4096
    assert choose_block_n(50, 2, 8) == 128    # lane-minimum floor
    for n in (50, 100, 129, 300, 900, 5000):
        bn = choose_block_n(n, 2, 8)
        assert bn >= 128
        assert bn <= max(n, 128), (n, bn)


def test_kernel_wrappers_handle_ragged_n():
    """Non-multiple-of-block n goes through the padded/masked path."""
    from repro.kernels import ops, ref
    pts = jax.random.normal(jax.random.PRNGKey(0), (337, 5))
    cents = jax.random.normal(jax.random.PRNGKey(1), (3, 5))
    md = jnp.full((337,), jnp.inf)
    got_md, partials = ops.distance_min_update(pts, cents, md)
    want_md, want_total = ref.distance_min_update_ref(pts, cents, md)
    np.testing.assert_allclose(np.asarray(got_md), np.asarray(want_md),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(jnp.sum(partials)), float(want_total),
                               rtol=1e-4)
