"""Worker for tests/test_distributed.py — runs under 8 fake CPU devices in a
SUBPROCESS (jax locks device count at init; the main pytest process keeps 1
device so smoke tests measure realistic single-device behaviour)."""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import (dist_kmeans, dist_kmeanspp, dist_lloyd, kmeanspp,
                        lloyd, quality, ring_psum, take_global)
from repro.data.synthetic import blobs

out = {}
mesh = jax.make_mesh((4, 2), ("data", "model"))

# ---------------------------------------------------------------------------
# 1. distributed k-means++ is a valid, quality-preserving seeding
# ---------------------------------------------------------------------------
pts_np, _ = blobs(4096, 2, 16, seed=0)
pts = jnp.asarray(pts_np)
key = jax.random.PRNGKey(0)

res_d = dist_kmeanspp(key, pts, 16, mesh=mesh, axes=("data", "model"))
res_s = kmeanspp(key, pts, 16)
phi_d = float(quality.inertia(pts, res_d.centroids))
phi_s = float(quality.inertia(pts, res_s.centroids))
out["dist_seeds_are_points"] = bool(np.allclose(
    np.asarray(res_d.centroids),
    np.asarray(pts)[np.asarray(res_d.indices)], rtol=1e-5))
out["dist_phi"] = phi_d
out["serial_phi"] = phi_s
out["dist_quality_ok"] = phi_d < 3 * phi_s

# min_d2 parity: the returned min_d2 must equal the true potential terms
md = np.asarray(res_d.min_d2)
true_md = np.min(np.asarray(
    quality.pairwise_d2(pts, res_d.centroids)
    if hasattr(quality, "pairwise_d2") else
    __import__("repro.core.kmeanspp", fromlist=["pairwise_d2"])
    .pairwise_d2(pts, res_d.centroids)), axis=1)
out["dist_min_d2_ok"] = bool(np.allclose(md, true_md, rtol=1e-4, atol=1e-5))

# ---------------------------------------------------------------------------
# 2. distributed Lloyd == single-device Lloyd (same seeds)
# ---------------------------------------------------------------------------
seeds = res_s.centroids
r_d = dist_lloyd(pts, seeds, mesh=mesh, axes=("data", "model"), max_iters=10)
r_s = lloyd(pts, seeds, max_iters=10)
out["lloyd_inertia_match"] = bool(np.isclose(float(r_d.inertia),
                                             float(r_s.inertia), rtol=1e-4))
out["lloyd_assign_match"] = bool(
    (np.asarray(r_d.assignment) == np.asarray(r_s.assignment)).mean() > 0.999)

# ---------------------------------------------------------------------------
# 3. collective helpers: take_global, ring_psum
# ---------------------------------------------------------------------------
x = jnp.arange(64, dtype=jnp.float32).reshape(16, 4)


def tg(idx):
    f = shard_map(
        lambda p: take_global(p, jnp.asarray(idx, jnp.int32),
                              ("data", "model")),
        mesh=mesh, in_specs=P(("data", "model")), out_specs=P())
    return f(x)


out["take_global_ok"] = all(
    np.allclose(np.asarray(tg(i)), np.asarray(x[i])) for i in (0, 7, 15))


def rp(v):
    # out_specs keeps the data axis: VMA can't statically prove a ppermute
    # ring is replicated, so each shard returns its copy and we check parity
    f = shard_map(
        lambda p: ring_psum(jnp.sum(p, keepdims=True), "data"),
        mesh=mesh, in_specs=P(("data",)), out_specs=P(("data",)))
    return f(v)


v = jnp.arange(8, dtype=jnp.float32)[:, None]
out["ring_psum_ok"] = bool(np.allclose(np.asarray(rp(v)),
                                       float(jnp.sum(v))))

# ---------------------------------------------------------------------------
# 4. gumbel seeding distribution parity: distributed sampler ∝ D^2
# ---------------------------------------------------------------------------
small = jnp.asarray([[0.0, 0.0]] * 30 + [[10.0, 0.0]] * 10, jnp.float32)
# after choosing point 0 (say), D^2 mass is concentrated on the far cluster
counts = np.zeros(2)
for s in range(120):
    r = dist_kmeanspp(jax.random.PRNGKey(s), small, 2, mesh=mesh,
                      axes=("data", "model"))
    counts[int(np.asarray(r.indices)[1] >= 30)] += 1
# P(second seed in far cluster) should be ~ (10*100)/(10*100 + small)
out["gumbel_far_fraction"] = float(counts[1] / counts.sum())
out["gumbel_dist_ok"] = counts[1] / counts.sum() > 0.7

# ---------------------------------------------------------------------------
# 4b. rejection seeding on the mesh: shared-stream pin + exact min_d2
# ---------------------------------------------------------------------------
from repro.core.engine import ClusterEngine

eng_m = ClusterEngine("mesh", mesh=mesh, axes=("data", "model"))
t_m = eng_m.seed(key, pts, 16, sampler="tiled")
r_m1 = eng_m.seed(key, pts, 16, sampler="rejection", refresh_block=1)
r_m8 = eng_m.seed(key, pts, 16, sampler="rejection", refresh_block=8)
out["mesh_rejection_pin_ok"] = bool(
    np.array_equal(np.asarray(t_m.indices), np.asarray(r_m1.indices)))
d2_m = jnp.min(jnp.sum((pts[:, None, :] - r_m8.centroids[None]) ** 2, -1), 1)
out["mesh_rejection_min_d2_ok"] = bool(np.allclose(
    np.asarray(r_m8.min_d2), np.asarray(d2_m), rtol=2e-4, atol=1e-3))
props_m = np.asarray(r_m8.proposals)
accs_m = np.asarray(r_m8.accepts)
out["mesh_rejection_counters_ok"] = bool(
    props_m.shape == (16,) and props_m[0] == 0 and accs_m[0] == 0
    and (accs_m <= props_m).all() and (props_m[1:] >= 1).all())

# coarse-to-fine on the mesh (ISSUE 9): r_m1/r_m8 above already run the
# default proposal='hier'; pin that flat at refresh_block=1 telescopes to
# the same stream, and that the hier counters obey the contract at rb=8
f_m1 = eng_m.seed(key, pts, 16, sampler="rejection", refresh_block=1,
                  proposal="flat")
out["mesh_hier_flat_pin_ok"] = bool(
    np.array_equal(np.asarray(t_m.indices), np.asarray(f_m1.indices)))
tg_m = np.asarray(r_m8.tightened)
sp_m = np.asarray(r_m8.supers)
out["mesh_hier_counters_ok"] = bool(
    tg_m.shape == (16,) and sp_m.shape == (16,)
    and tg_m[0] == 0 and sp_m[0] == 0
    and (props_m <= sp_m).all() and (sp_m <= props_m + 1).all())
f_m8 = eng_m.seed(key, pts, 16, sampler="rejection", refresh_block=8,
                  proposal="flat")
out["mesh_flat_counters_zero_ok"] = bool(
    (np.asarray(f_m8.tightened) == 0).all()
    and (np.asarray(f_m8.supers) == 0).all())

# ---------------------------------------------------------------------------
# 4c. dist_gumbel_topl: exact distributed top-l == replicated gumbel_topk,
#     and the k-means|| mesh init built on it returns valid seeds
# ---------------------------------------------------------------------------
from repro.core import collectives, sampling
from repro.core.kmeans_parallel import kmeans_parallel_init

lw = sampling.safe_log(jnp.abs(jnp.asarray(
    np.random.default_rng(7).normal(size=4096), jnp.float32)) + 1e-3)
ktop = jax.random.PRNGKey(21)


def topl_dist(l):
    f = shard_map(
        lambda w: collectives.dist_gumbel_topl(ktop, w, l,
                                               ("data", "model"))[0],
        mesh=mesh, in_specs=P(("data", "model")), out_specs=P())
    return f(lw)


# parity oracle: same per-shard fold_in key schedule, replicated
def topl_ref(l):
    S, n_loc = 8, 4096 // 8
    scores = []
    for s in range(S):
        g = lw[s * n_loc:(s + 1) * n_loc] + jax.random.gumbel(
            jax.random.fold_in(ktop, s), (n_loc,), jnp.float32)
        scores.append(g)
    allg = jnp.concatenate(scores)
    _, idx = jax.lax.top_k(allg, l)
    return idx


got = np.sort(np.asarray(topl_dist(32)))
want = np.sort(np.asarray(topl_ref(32)))
out["dist_gumbel_topl_ok"] = bool(np.array_equal(got, want))

kp = kmeans_parallel_init(jax.random.PRNGKey(22), pts, 16, rounds=3,
                          backend=eng_m.backend)
phi_p = float(np.sum(np.asarray(kp.min_d2)))
out["mesh_kmeans_parallel_phi"] = phi_p
out["mesh_kmeans_parallel_ok"] = bool(
    np.allclose(np.asarray(kp.centroids),
                np.asarray(pts)[np.asarray(kp.indices)], rtol=1e-5)
    and phi_p < 3 * phi_s)

# ---------------------------------------------------------------------------
# 5. checkpoint reshard restore (elasticity): save on (4,2), load on (2,4)
# ---------------------------------------------------------------------------
from repro.checkpoint.manager import CheckpointManager
import tempfile

with tempfile.TemporaryDirectory() as td:
    mgr = CheckpointManager(td, async_save=False)
    w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(16, 4),
                       NamedSharding(mesh, P("data", "model")))
    mgr.save(1, {"w": w})
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    _, got = mgr.restore({"w": jnp.zeros((16, 4))},
                         shardings={"w": NamedSharding(mesh2,
                                                       P("data", "model"))})
    out["reshard_values_ok"] = bool(np.allclose(np.asarray(got["w"]),
                                                np.asarray(w)))
    out["reshard_sharding_ok"] = got["w"].sharding.spec == P("data", "model")

# ---------------------------------------------------------------------------
# 6. sharded train step == single-device train step (tiny arch)
# ---------------------------------------------------------------------------
from repro.configs.registry import get_config
from repro.launch.step import (init_train_state, make_train_step,
                               train_state_shardings)
from repro.models.sharding import use_mesh
from repro.optim import AdamWConfig

cfg = get_config("deepseek-7b", smoke=True)
opt = AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
kb = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(kb, (8, 32), 0, cfg.vocab),
         "labels": jax.random.randint(kb, (8, 32), 0, cfg.vocab)}

state0 = init_train_state(cfg, jax.random.PRNGKey(0))
_, m_single = jax.jit(make_train_step(cfg, opt))(state0, batch)

with use_mesh(mesh):
    ssh = train_state_shardings(mesh, state0)
    state_sharded = jax.device_put(state0, ssh)
    bsh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
    jf = jax.jit(make_train_step(cfg, opt), in_shardings=(ssh, bsh),
                 out_shardings=(ssh, None))
    _, m_shard = jf(state_sharded, jax.device_put(batch, bsh))

out["sharded_loss"] = float(m_shard["loss"])
out["single_loss"] = float(m_single["loss"])
out["train_step_parity"] = bool(np.isclose(float(m_shard["loss"]),
                                           float(m_single["loss"]),
                                           rtol=2e-3, atol=2e-3))

print(json.dumps(out, default=lambda o: bool(o) if isinstance(o, np.bool_)
                 else float(o)))
sys.exit(0 if all(v for k, v in out.items()
                  if k.endswith("_ok") or k.endswith("parity")
                  or k.endswith("match")) else 1)
