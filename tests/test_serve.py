"""Serving engine + KV product quantization (paper integration #1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.registry import get_model
from repro.serve import Engine, ServeConfig, kvquant


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("deepseek-7b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_generates(small_lm):
    cfg, _, params = small_lm
    eng = Engine(cfg, params, ServeConfig(max_batch=4, max_len=48,
                                          max_new_tokens=8))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 13, 7, 11)]       # 5 requests > max_batch=4
    outs = eng.generate(prompts)
    assert len(outs) == 5
    assert all(len(o) == 8 for o in outs)
    assert all((0 <= o).all() and (o < cfg.padded_vocab).all() for o in outs)


def test_engine_greedy_deterministic(small_lm):
    cfg, _, params = small_lm
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=32,
                                          max_new_tokens=6))
    p = [np.arange(8, dtype=np.int32) % cfg.vocab]
    a = eng.generate(p)[0]
    b = eng.generate(p)[0]
    np.testing.assert_array_equal(a, b)


def test_engine_matches_manual_decode(small_lm):
    """Engine greedy output == hand-rolled prefill+decode loop."""
    cfg, model, params = small_lm
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32)
    eng = Engine(cfg, params, ServeConfig(max_batch=1, max_len=32,
                                          max_new_tokens=4))
    got = eng.generate([prompt])[0]

    logits, cache = model.prefill(params,
                                  {"tokens": jnp.asarray(prompt)[None]},
                                  cache_len=32)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        toks.append(int(tok[0]))
        logits, cache = model.decode_step(params, tok[:, None], cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(got, np.asarray(toks, np.int32))


def test_eos_stops_early(small_lm):
    cfg, model, params = small_lm
    # find the greedy first token, then set THAT as eos
    logits, _ = model.prefill(params, {"tokens": jnp.asarray([[1, 2, 3]])},
                              cache_len=16)
    eos = int(jnp.argmax(logits, -1)[0])
    eng = Engine(cfg, params, ServeConfig(max_batch=1, max_len=32,
                                          max_new_tokens=8, eos_id=eos))
    out = eng.generate([np.asarray([1, 2, 3], np.int32)])[0]
    assert len(out) == 1 and out[0] == eos


# ---------------------------------------------------------------------------
# KV product quantization
# ---------------------------------------------------------------------------

def test_kvquant_roundtrip_quality():
    key = jax.random.PRNGKey(0)
    # KV-like data: per-head vectors with strong low-rank structure
    base = jax.random.normal(key, (16, 64))
    coef = jax.random.normal(jax.random.fold_in(key, 1), (2048, 16))
    kv = (coef @ base + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 2), (2048, 64))).astype(jnp.bfloat16)
    pq = kvquant.compress_kv(key, kv, n_sub=8)
    err = float(kvquant.reconstruction_error(kv, pq))
    assert err < 0.25, err    # 32x compression on rank-16 + 5% noise data
    assert pq.codes.shape == (2048, 8) and pq.codes.dtype == jnp.uint8


def test_kvquant_more_subvectors_less_error():
    key = jax.random.PRNGKey(1)
    kv = jax.random.normal(key, (1024, 64))
    e2 = float(kvquant.reconstruction_error(
        kv, kvquant.compress_kv(key, kv, n_sub=2)))
    e8 = float(kvquant.reconstruction_error(
        kv, kvquant.compress_kv(key, kv, n_sub=8)))
    assert e8 < e2, (e8, e2)


def test_kvquant_compression_ratio():
    # codebook amortizes over the cache: long caches approach d*2/n_sub = 32x
    kv = jnp.zeros((32768, 128), jnp.bfloat16)
    pq = kvquant.compress_kv(jax.random.PRNGKey(0), kv, n_sub=8,
                             lloyd_iters=1)
    assert kvquant.compression_ratio(kv, pq) > 15


def test_kvquant_encode_decode_shapes():
    key = jax.random.PRNGKey(2)
    kv = jax.random.normal(key, (4, 32, 8, 64))      # (L, S, KH, hd)
    cb = kvquant.build_codebook(key, kv.reshape(-1, 64), n_sub=4)
    codes = kvquant.encode(kv, cb)
    assert codes.shape == (4, 32, 8, 4)
    rec = kvquant.decode(codes, cb)
    assert rec.shape == kv.shape


# ---------------------------------------------------------------------------
# request guards + group timeout (ISSUE 7 satellites): one bad request must
# not crash — or stall — the batch
# ---------------------------------------------------------------------------


def test_malformed_prompts_get_typed_per_request_errors(small_lm):
    from repro.serve import RequestError
    cfg, _, params = small_lm
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=16,
                                          max_new_tokens=4))
    good = np.asarray([1, 2, 3], np.int32)
    prompts = [good,
               np.asarray([], np.int32),                  # empty
               np.asarray([0.5, 1.5], np.float32),        # float tokens
               np.arange(17, dtype=np.int32),             # > max_len
               np.zeros((2, 3), np.int32),                # not 1-D
               good]
    outs = eng.generate(prompts)
    assert len(outs) == 6
    assert isinstance(outs[1], RequestError) and "empty" in outs[1].reason
    assert isinstance(outs[2], RequestError) and "dtype" in outs[2].reason
    assert isinstance(outs[3], RequestError) and "max_len" in outs[3].reason
    assert isinstance(outs[4], RequestError) and "1-D" in outs[4].reason
    for bad_idx in (1, 2, 3, 4):
        assert outs[bad_idx].index == bad_idx
    # the valid slots are still served, in order
    assert isinstance(outs[0], np.ndarray) and len(outs[0]) == 4
    assert isinstance(outs[5], np.ndarray) and len(outs[5]) == 4


def test_all_valid_batch_is_bitwise_the_unguarded_grouping(small_lm):
    """Per-request validation must not perturb the healthy path: a batch of
    valid prompts reproduces the pre-guard outputs (same groups, same key
    folds) bitwise."""
    cfg, _, params = small_lm
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=32,
                                          max_new_tokens=4))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=m).astype(np.int32)
               for m in (5, 9, 7, 11, 6)]
    a = eng.generate(prompts, seed=3)
    b = eng.generate(prompts, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # dropping an invalid slot must not change the key schedule of the
    # groups that remain: valid outputs are those of the valid-only call
    with_bad = prompts[:2] + [np.asarray([], np.int32)] + prompts[2:]
    mixed = eng.generate(with_bad, seed=3)
    for got, want in zip(mixed[:2] + mixed[3:], a):
        np.testing.assert_array_equal(got, want)


def test_group_timeout_returns_partial_completions(small_lm):
    from repro.serve import RequestError
    cfg, _, params = small_lm
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=32,
                                          max_new_tokens=64,
                                          group_timeout=0.0))
    p = [np.asarray([1, 2, 3], np.int32)]
    out = eng.generate(p)[0]
    # deadline expires before the first decode step: only the prefill token
    assert not isinstance(out, RequestError)
    assert 1 <= len(out) < 64
    # unbounded config still decodes to max_new_tokens
    eng2 = Engine(cfg, params, ServeConfig(max_batch=2, max_len=32,
                                           max_new_tokens=8))
    assert len(eng2.generate(p)[0]) == 8
