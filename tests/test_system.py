"""End-to-end behaviour tests for the paper's system: the full pipeline
(cluster -> train-with-kmeans-features -> serve) on CPU-sized configs."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_NAMES
from repro.core import kmeans, kmeanspp, quality
from repro.data.synthetic import blobs

ROOT = Path(__file__).parents[1]


def _run(args, timeout=900):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, timeout=timeout, env=env, cwd=ROOT)


def test_paper_workload_end_to_end():
    """The paper's experiment in miniature: cluster blobs, serial == parallel
    seeds, clustering quality preserved (the paper's central claim)."""
    pts, labels = blobs(8192, 2, 50, seed=0)     # paper: d=2, k up to 100
    pts = jnp.asarray(pts)
    key = jax.random.PRNGKey(0)
    res_serial = kmeanspp(key, pts, 50, variant="serial", sampler="cdf")
    res_fused = kmeanspp(key, pts, 50, variant="fused", sampler="cdf")
    np.testing.assert_array_equal(np.asarray(res_serial.indices),
                                  np.asarray(res_fused.indices))
    out = kmeans(key, pts, 50, variant="fused", max_iters=30)
    # recovered clustering must explain the blob structure
    assert float(out.inertia) / 8192 < 3 * 2 * 0.05 ** 2


def test_train_driver_loss_decreases(tmp_path):
    """CLI end-to-end: 30 steps on the smoke model, loss must fall (the
    full few-hundred-step run lives in examples/train_lm.py)."""
    proc = _run(["-m", "repro.launch.train", "--arch", "deepseek-7b",
                 "--smoke", "--steps", "30", "--batch", "4", "--seq", "64",
                 "--lr", "3e-3", "--ckpt-dir", str(tmp_path / "ck")])
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if "loss first" in l][0]
    parts = line.split()
    first = float(parts[parts.index("first-3-mean") + 1])
    last = float(parts[parts.index("last-3-mean") + 1])
    assert last < first, line


def test_serve_driver_runs():
    proc = _run(["-m", "repro.launch.serve", "--arch", "gemma2-2b",
                 "--smoke", "--requests", "5", "--prompt-len", "16",
                 "--max-new", "4", "--batch", "4"])
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "tok/s" in proc.stdout


def test_registry_covers_assignment():
    assert len(ARCH_NAMES) == 10
