"""Distributed k-means++ / sharding tests. Runs in a SUBPROCESS with 8 fake
CPU devices (jax locks the device count at first init; the main test process
must keep 1 device so other tests see realistic single-device behaviour)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

_WORKER = Path(__file__).parent / "distributed_worker.py"


@pytest.fixture(scope="module")
def worker_out():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, str(_WORKER)], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, \
        f"worker failed\nstdout: {proc.stdout[-4000:]}\nstderr: {proc.stderr[-4000:]}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_dist_seeds_are_points(worker_out):
    assert worker_out["dist_seeds_are_points"]


def test_dist_quality_parity(worker_out):
    assert worker_out["dist_quality_ok"], \
        (worker_out["dist_phi"], worker_out["serial_phi"])


def test_dist_min_d2(worker_out):
    assert worker_out["dist_min_d2_ok"]


def test_dist_lloyd_matches_single(worker_out):
    assert worker_out["lloyd_inertia_match"]
    assert worker_out["lloyd_assign_match"]


def test_take_global(worker_out):
    assert worker_out["take_global_ok"]


def test_ring_psum(worker_out):
    assert worker_out["ring_psum_ok"]


def test_distributed_gumbel_distribution(worker_out):
    assert worker_out["gumbel_dist_ok"], worker_out["gumbel_far_fraction"]


def test_mesh_rejection_sampler(worker_out):
    assert worker_out["mesh_rejection_pin_ok"]
    assert worker_out["mesh_rejection_min_d2_ok"]
    assert worker_out["mesh_rejection_counters_ok"]


def test_mesh_coarse_to_fine_proposal(worker_out):
    assert worker_out["mesh_hier_flat_pin_ok"]
    assert worker_out["mesh_hier_counters_ok"]
    assert worker_out["mesh_flat_counters_zero_ok"]


def test_dist_gumbel_topl_exact(worker_out):
    assert worker_out["dist_gumbel_topl_ok"]


def test_mesh_kmeans_parallel_init(worker_out):
    assert worker_out["mesh_kmeans_parallel_ok"], \
        (worker_out["mesh_kmeans_parallel_phi"], worker_out["serial_phi"])


def test_checkpoint_reshard_elastic(worker_out):
    assert worker_out["reshard_values_ok"]
    assert worker_out["reshard_sharding_ok"]


def test_sharded_train_step_parity(worker_out):
    assert worker_out["train_step_parity"], \
        (worker_out["sharded_loss"], worker_out["single_loss"])
