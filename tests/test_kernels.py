"""Pallas kernel tests: interpret-mode execution swept over shapes/dtypes,
assert_allclose against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.kmeans_distance import distance_min_update_pallas
from repro.kernels.lloyd_assign import lloyd_assign_pallas


def _mk(n, d, k, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    pts = jax.random.normal(k1, (n, d), dtype)
    cents = jax.random.normal(k2, (k, d), dtype)
    md = jnp.abs(jax.random.normal(k3, (n,), jnp.float32)) * 4
    return pts, cents, md


SHAPES = [  # (n, d, k_new, block_n) — ragged edges, tiny dims, big tiles
    (128, 2, 1, 128),
    (100, 2, 1, 128),          # n < block, padded tail
    (1000, 3, 1, 256),         # ragged
    (1024, 64, 1, 256),
    (513, 128, 2, 128),        # multiple new centroids + ragged
    (4096, 8, 4, 1024),
]


@pytest.mark.parametrize("n,d,k,block_n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distance_min_update_matches_ref(n, d, k, block_n, dtype):
    pts, cents, md = _mk(n, d, k, dtype)
    got_md, partials = distance_min_update_pallas(
        pts, cents, md, block_n=block_n, interpret=True)
    want_md, want_total = ref.distance_min_update_ref(pts, cents, md)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got_md), np.asarray(want_md),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(jnp.sum(partials)), float(want_total),
                               rtol=tol * max(n, 1))


@pytest.mark.parametrize("resident", [True, False])
def test_distance_kernel_resident_vs_streamed(resident):
    """Constant-memory analogue (resident) and global analogue agree exactly."""
    pts, cents, md = _mk(777, 16, 1, jnp.float32)
    got_md, _ = distance_min_update_pallas(pts, cents, md, block_n=128,
                                           resident=resident, interpret=True)
    want_md, _ = ref.distance_min_update_ref(pts, cents, md)
    np.testing.assert_allclose(np.asarray(got_md), np.asarray(want_md),
                               rtol=1e-5, atol=1e-6)


ASSIGN_SHAPES = [
    (128, 2, 4, 128),
    (1000, 8, 16, 256),
    (513, 64, 7, 128),
    (2048, 32, 50, 512),
]


@pytest.mark.parametrize("n,d,k,block_n", ASSIGN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lloyd_assign_matches_ref(n, d, k, block_n, dtype):
    pts, cents, _ = _mk(n, d, k, dtype, seed=3)
    a, md, sums, counts = lloyd_assign_pallas(pts, cents, block_n=block_n,
                                              interpret=True)
    a_ref, md_ref, sums_ref, counts_ref = ref.lloyd_assign_ref(pts, cents)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    # ties can differ between argmin orders only when distances are equal —
    # random data: assert exact match
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    np.testing.assert_allclose(np.asarray(md), np.asarray(md_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_ref),
                               rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_ref),
                               rtol=0, atol=0)


def test_ops_dispatch_and_block_pick():
    """ops.* wrappers pick a legal block size and agree with refs."""
    pts, cents, md = _mk(900, 7, 1, jnp.float32, seed=9)
    got_md, partials = ops.distance_min_update(pts, cents, md)
    want_md, want_total = ref.distance_min_update_ref(pts, cents, md)
    np.testing.assert_allclose(np.asarray(got_md), np.asarray(want_md),
                               rtol=1e-5, atol=1e-6)
    a, md2, sums, counts = ops.lloyd_assign(pts, cents.repeat(3, 0))
    assert a.shape == (900,) and sums.shape == (3, 7)
    assert ops.pick_block_n(4096, 256) >= 128
    assert ops.pick_block_n(2, 8) == 4096


# ---------------------------------------------------------------------------
# batch-grid kernels (multi-tenant clustering)
# ---------------------------------------------------------------------------

BATCHED_SHAPES = [  # (B, n, d, k, block_n)
    (2, 128, 2, 1, 128),
    (3, 300, 4, 2, 128),       # ragged n
    (2, 1024, 16, 4, 256),
]


@pytest.mark.parametrize("B,n,d,k,block_n", BATCHED_SHAPES)
def test_distance_min_update_batched_matches_per_problem(B, n, d, k, block_n):
    from repro.kernels.kmeans_distance import (
        distance_min_update_batched_pallas)
    pts = jax.random.normal(jax.random.PRNGKey(0), (B, n, d))
    cents = jax.random.normal(jax.random.PRNGKey(1), (B, k, d))
    md = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (B, n))) * 4
    got_md, got_p = distance_min_update_batched_pallas(
        pts, cents, md, block_n=block_n, interpret=True)
    assert got_p.shape == (B, -(-n // block_n))
    for b in range(B):
        want_md, want_p = distance_min_update_pallas(
            pts[b], cents[b], md[b], block_n=block_n, interpret=True)
        # row b of the batch-grid launch is bitwise the single-problem kernel
        np.testing.assert_array_equal(np.asarray(got_md[b]),
                                      np.asarray(want_md))
        np.testing.assert_array_equal(np.asarray(got_p[b]),
                                      np.asarray(want_p))


@pytest.mark.parametrize("B,n,d,k,block_n", BATCHED_SHAPES)
def test_lloyd_assign_batched_matches_per_problem(B, n, d, k, block_n):
    from repro.kernels.lloyd_assign import lloyd_assign_batched_pallas
    k = max(k, 2)
    pts = jax.random.normal(jax.random.PRNGKey(3), (B, n, d))
    cents = jax.random.normal(jax.random.PRNGKey(4), (B, k, d))
    a, md, sums, counts = lloyd_assign_batched_pallas(
        pts, cents, block_n=block_n, interpret=True)
    for b in range(B):
        a1, md1, s1, c1 = lloyd_assign_pallas(pts[b], cents[b],
                                              block_n=block_n, interpret=True)
        np.testing.assert_array_equal(np.asarray(a[b]), np.asarray(a1))
        np.testing.assert_array_equal(np.asarray(md[b]), np.asarray(md1))
        np.testing.assert_array_equal(np.asarray(sums[b]), np.asarray(s1))
        np.testing.assert_array_equal(np.asarray(counts[b]), np.asarray(c1))


def test_ops_vmap_dispatches_to_batch_grid_kernel():
    """jax.vmap over the ops wrappers must lower to ONE batch-grid pallas
    call, not B per-problem calls (the custom_vmap rule)."""
    B, n, d, k = 3, 256, 4, 2
    pts = jax.random.normal(jax.random.PRNGKey(5), (B, n, d))
    cents = jax.random.normal(jax.random.PRNGKey(6), (B, k, d))
    md = jnp.full((B, n), jnp.inf)

    out_md, partials = jax.vmap(
        lambda p, c, m: ops.distance_min_update(p, c, m))(pts, cents, md)
    for b in range(B):
        want_md, want_total = ref.distance_min_update_ref(pts[b], cents[b],
                                                          md[b])
        np.testing.assert_allclose(np.asarray(out_md[b]), np.asarray(want_md),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(jnp.sum(partials[b])),
                                   float(want_total), rtol=1e-4)

    a, md2, sums, counts = jax.vmap(
        lambda p, c: ops.lloyd_assign(p, c))(pts, cents)
    for b in range(B):
        a_ref, md_ref, s_ref, c_ref = ref.lloyd_assign_ref(pts[b], cents[b])
        np.testing.assert_array_equal(np.asarray(a[b]), np.asarray(a_ref))
        np.testing.assert_allclose(np.asarray(s_ref), np.asarray(sums[b]),
                                   rtol=1e-5, atol=1e-4)


def test_pick_block_n_batched_accounting():
    """The batch-grid accounting (extra in-flight centroid block) can only
    shrink the tile, and the partials/accumulator terms keep the historical
    picks for small shapes."""
    assert ops.pick_block_n(2, 8) == 4096
    assert ops.pick_block_n(2, 8, batched=True) == 4096
    for d, k in ((2, 8), (64, 256), (512, 1024), (4096, 256)):
        assert ops.pick_block_n(d, k, batched=True) <= ops.pick_block_n(d, k)
        assert ops.pick_block_n(d, k, batched=True) >= 128


def test_kernel_inside_seeding_loop():
    """Pallas round used end-to-end inside kmeanspp gives identical seeds."""
    from repro.core import kmeanspp
    pts, _, _ = _mk(512, 4, 1, jnp.float32, seed=11)
    key = jax.random.PRNGKey(5)
    ref_res = kmeanspp(key, pts, 7, variant="fused", sampler="cdf")
    pal_res = kmeanspp(key, pts, 7, variant="pallas_fused", sampler="cdf")
    np.testing.assert_array_equal(np.asarray(ref_res.indices),
                                  np.asarray(pal_res.indices))


# ---------------------------------------------------------------------------
# flash attention kernel (memory-term §Perf kernel)
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, Skv, H, KH, hd, causal, window, cap, bq, bk)
    (2, 128, 128, 4, 2, 32, True, 0, 0.0, 64, 64),
    (1, 200, 200, 4, 4, 16, True, 0, 0.0, 64, 64),      # ragged seq
    (2, 64, 256, 8, 2, 32, False, 0, 0.0, 64, 128),     # cross attention
    (1, 256, 256, 2, 1, 64, True, 64, 50.0, 64, 64),    # window + softcap
    (1, 96, 96, 2, 2, 128, True, 0, 0.0, 32, 32),       # hd 128
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    from repro.kernels.flash_attention import flash_attention
    B, Sq, Skv, H, KH, hd, causal, window, cap, bq, bk = case
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(keys[1], (B, Skv, KH, hd), dtype)
    v = jax.random.normal(keys[2], (B, Skv, KH, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_decode_offset():
    """q_offset (chunked prefill / decode) masks exactly like the oracle."""
    from repro.kernels.flash_attention import flash_attention
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (1, 32, 2, 32))
    k = jax.random.normal(keys[1], (1, 128, 2, 32))
    v = jax.random.normal(keys[2], (1, 128, 2, 32))
    got = flash_attention(q, k, v, causal=True, q_offset=64,
                          block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
