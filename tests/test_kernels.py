"""Pallas kernel tests: interpret-mode execution swept over shapes/dtypes,
assert_allclose against the pure-jnp oracles in kernels/ref.py, plus
BITWISE pins between the plain and bound-gated kernel paths (tile skipping
is exact) and between single and batch-grid launches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bounds
from repro.kernels import ops, ref
from repro.kernels.kmeans_distance import (distance_min_update_gated_pallas,
                                           distance_min_update_pallas,
                                           seed_prologue_pallas)
from repro.kernels.lloyd_assign import lloyd_assign_pallas


def _mk(n, d, k, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    pts = jax.random.normal(k1, (n, d), dtype)
    cents = jax.random.normal(k2, (k, d), dtype)
    md = jnp.abs(jax.random.normal(k3, (n,), jnp.float32)) * 4
    return pts, cents, md


SHAPES = [  # (n, d, k_new, block_n) — ragged edges, tiny dims, big tiles
    (128, 2, 1, 128),
    (100, 2, 1, 128),          # n < block, padded tail
    (1000, 3, 1, 256),         # ragged
    (1024, 64, 1, 256),
    (513, 128, 2, 128),        # multiple new centroids + ragged
    (4096, 8, 4, 1024),
]


@pytest.mark.parametrize("n,d,k,block_n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distance_min_update_matches_ref(n, d, k, block_n, dtype):
    pts, cents, md = _mk(n, d, k, dtype)
    got_md, partials = distance_min_update_pallas(
        pts, ops.point_norms(pts), cents, md, block_n=block_n,
        resident=True, interpret=True)
    want_md, want_total = ref.distance_min_update_ref(pts, cents, md)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got_md), np.asarray(want_md),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(jnp.sum(partials)), float(want_total),
                               rtol=tol * max(n, 1))


@pytest.mark.parametrize("resident", [True, False])
def test_distance_kernel_resident_vs_streamed(resident):
    """Constant-memory analogue (resident) and global analogue agree exactly."""
    pts, cents, md = _mk(777, 16, 1, jnp.float32)
    got_md, _ = distance_min_update_pallas(pts, ops.point_norms(pts), cents,
                                           md, block_n=128,
                                           resident=resident, interpret=True)
    want_md, _ = ref.distance_min_update_ref(pts, cents, md)
    np.testing.assert_allclose(np.asarray(got_md), np.asarray(want_md),
                               rtol=1e-5, atol=1e-6)


def test_raw_kernels_require_explicit_interpret():
    """`ops` is the single place the interpret default lives: the raw kernel
    entry points must refuse to run without an explicit choice (silently
    interpreting on a real TPU was the failure mode)."""
    pts, cents, md = _mk(128, 2, 1, jnp.float32)
    nrm = ops.point_norms(pts)
    with pytest.raises(TypeError):
        distance_min_update_pallas(pts, nrm, cents, md, block_n=128,
                                   resident=True)
    with pytest.raises(TypeError):
        lloyd_assign_pallas(pts, nrm, cents, block_n=128)
    with pytest.raises(TypeError):
        seed_prologue_pallas(pts, block_n=128)


ASSIGN_SHAPES = [
    (128, 2, 4, 128),
    (1000, 8, 16, 256),
    (513, 64, 7, 128),
    (2048, 32, 50, 512),
]


@pytest.mark.parametrize("n,d,k,block_n", ASSIGN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lloyd_assign_matches_ref(n, d, k, block_n, dtype):
    pts, cents, _ = _mk(n, d, k, dtype, seed=3)
    a, md, sums, counts = lloyd_assign_pallas(pts, ops.point_norms(pts),
                                              cents, block_n=block_n,
                                              interpret=True)
    a_ref, md_ref, sums_ref, counts_ref = ref.lloyd_assign_ref(pts, cents)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    # ties can differ between argmin orders only when distances are equal —
    # random data: assert exact match
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    np.testing.assert_allclose(np.asarray(md), np.asarray(md_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_ref),
                               rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_ref),
                               rtol=0, atol=0)


def test_ops_dispatch_and_block_pick():
    """ops.* wrappers pick a legal block size and agree with refs."""
    pts, cents, md = _mk(900, 7, 1, jnp.float32, seed=9)
    got_md, partials = ops.distance_min_update(pts, cents, md)
    want_md, want_total = ref.distance_min_update_ref(pts, cents, md)
    np.testing.assert_allclose(np.asarray(got_md), np.asarray(want_md),
                               rtol=1e-5, atol=1e-6)
    a, md2, sums, counts = ops.lloyd_assign(pts, cents.repeat(3, 0))
    assert a.shape == (900,) and sums.shape == (3, 7)
    assert ops.pick_block_n(4096, 256) >= 128
    assert ops.pick_block_n(2, 8) == 4096


# ---------------------------------------------------------------------------
# batch-grid kernels (multi-tenant clustering)
# ---------------------------------------------------------------------------

BATCHED_SHAPES = [  # (B, n, d, k, block_n)
    (2, 128, 2, 1, 128),
    (3, 300, 4, 2, 128),       # ragged n
    (2, 1024, 16, 4, 256),
]


@pytest.mark.parametrize("B,n,d,k,block_n", BATCHED_SHAPES)
def test_distance_min_update_batched_matches_per_problem(B, n, d, k, block_n):
    from repro.kernels.kmeans_distance import (
        distance_min_update_batched_pallas)
    pts = jax.random.normal(jax.random.PRNGKey(0), (B, n, d))
    cents = jax.random.normal(jax.random.PRNGKey(1), (B, k, d))
    md = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (B, n))) * 4
    nrm = jax.vmap(ops.point_norms)(pts)
    got_md, got_p = distance_min_update_batched_pallas(
        pts, nrm, cents, md, block_n=block_n, interpret=True)
    assert got_p.shape == (B, -(-n // block_n))
    for b in range(B):
        want_md, want_p = distance_min_update_pallas(
            pts[b], nrm[b], cents[b], md[b], block_n=block_n,
            resident=True, interpret=True)
        # row b of the batch-grid launch is bitwise the single-problem kernel
        np.testing.assert_array_equal(np.asarray(got_md[b]),
                                      np.asarray(want_md))
        np.testing.assert_array_equal(np.asarray(got_p[b]),
                                      np.asarray(want_p))


@pytest.mark.parametrize("B,n,d,k,block_n", BATCHED_SHAPES)
def test_lloyd_assign_batched_matches_per_problem(B, n, d, k, block_n):
    from repro.kernels.lloyd_assign import lloyd_assign_batched_pallas
    k = max(k, 2)
    pts = jax.random.normal(jax.random.PRNGKey(3), (B, n, d))
    cents = jax.random.normal(jax.random.PRNGKey(4), (B, k, d))
    nrm = jax.vmap(ops.point_norms)(pts)
    a, md, sums, counts = lloyd_assign_batched_pallas(
        pts, nrm, cents, block_n=block_n, interpret=True)
    for b in range(B):
        a1, md1, s1, c1 = lloyd_assign_pallas(pts[b], nrm[b], cents[b],
                                              block_n=block_n, interpret=True)
        np.testing.assert_array_equal(np.asarray(a[b]), np.asarray(a1))
        np.testing.assert_array_equal(np.asarray(md[b]), np.asarray(md1))
        np.testing.assert_array_equal(np.asarray(sums[b]), np.asarray(s1))
        np.testing.assert_array_equal(np.asarray(counts[b]), np.asarray(c1))


def test_ops_vmap_dispatches_to_batch_grid_kernel():
    """jax.vmap over the ops wrappers must lower to ONE batch-grid pallas
    call, not B per-problem calls (the custom_vmap rule)."""
    B, n, d, k = 3, 256, 4, 2
    pts = jax.random.normal(jax.random.PRNGKey(5), (B, n, d))
    cents = jax.random.normal(jax.random.PRNGKey(6), (B, k, d))
    md = jnp.full((B, n), jnp.inf)

    out_md, partials = jax.vmap(
        lambda p, c, m: ops.distance_min_update(p, c, m))(pts, cents, md)
    for b in range(B):
        want_md, want_total = ref.distance_min_update_ref(pts[b], cents[b],
                                                          md[b])
        np.testing.assert_allclose(np.asarray(out_md[b]), np.asarray(want_md),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(jnp.sum(partials[b])),
                                   float(want_total), rtol=1e-4)

    a, md2, sums, counts = jax.vmap(
        lambda p, c: ops.lloyd_assign(p, c))(pts, cents)
    for b in range(B):
        a_ref, md_ref, s_ref, c_ref = ref.lloyd_assign_ref(pts[b], cents[b])
        np.testing.assert_array_equal(np.asarray(a[b]), np.asarray(a_ref))
        np.testing.assert_allclose(np.asarray(s_ref), np.asarray(sums[b]),
                                   rtol=1e-5, atol=1e-4)


def test_pick_block_n_batched_accounting():
    """The batch-grid accounting (extra in-flight centroid block) can only
    shrink the tile, and the partials/accumulator terms keep the historical
    picks for small shapes."""
    assert ops.pick_block_n(2, 8) == 4096
    assert ops.pick_block_n(2, 8, batched=True) == 4096
    for d, k in ((2, 8), (64, 256), (512, 1024), (4096, 256)):
        assert ops.pick_block_n(d, k, batched=True) <= ops.pick_block_n(d, k)
        assert ops.pick_block_n(d, k, batched=True) >= 128


def test_pick_block_n_accounts_norms_and_bound_state():
    """`pick_block_n` and its mirror tests used to hand-copy the VMEM
    working-set formula — and the copies drifted (ISSUE 8 satellite).
    `ops.vmem_working_set` is now the single shared budget table: the pick
    must be the LARGEST power of two whose summed working set fits the
    budget (maximality: doubling must NOT fit unless capped), and the
    itemized table must name every buffer family the kernels keep
    resident."""
    budget = ops._VMEM_BUDGET
    for d, k in ((2, 8), (64, 256), (512, 1024), (4096, 256)):
        bn = ops.pick_block_n(d, k)

        def working(b):
            return sum(ops.vmem_working_set(d, k, b).values())

        assert working(bn) <= budget or bn == 128
        if bn < 4096:
            assert working(2 * bn) > budget


def test_vmem_working_set_is_the_shared_budget_table():
    """The itemized table IS the accounting `pick_block_n` sums — and the
    buffer families the kernels keep resident are all present by name, so
    a kernel change that adds a resident buffer has exactly one place to
    record it (and this test to update)."""
    ws = ops.vmem_working_set(64, 256, 1024)
    assert set(ws) == {"stream", "norms", "accumulators", "bound_scalars",
                      "super_accumulators", "point_carries", "center_d",
                      "movement", "gate_scalars"}
    assert all(v > 0 for v in ws.values())
    # the batched grid keeps one extra in-flight centroid block resident
    wsb = ops.vmem_working_set(64, 256, 1024, batched=True)
    assert set(wsb) - set(ws) == {"batched_centroids"}
    assert wsb["batched_centroids"] == 4 * 256 * 64
    # dtype_bytes halves exactly the streaming term, nothing else
    ws2 = ops.vmem_working_set(64, 256, 1024, dtype_bytes=2)
    assert ws2["stream"] == ws["stream"] // 2
    assert {k: v for k, v in ws2.items() if k != "stream"} == \
        {k: v for k, v in ws.items() if k != "stream"}


def test_pick_block_n_per_point_buffers_shrink_or_hold_the_pick():
    """Adding the per-point bound buffers (4 extra fp32-equivalent streams
    per row) can only shrink the tile vs a hypothetical pick without them —
    and at the paper's shapes the pick is unchanged (the buffers are small
    next to the point block)."""
    assert ops.pick_block_n(2, 8) == 4096          # paper shapes: unchanged
    for d, k in ((2, 8), (64, 256), (512, 1024), (4096, 256), (8192, 512)):
        bn = ops.pick_block_n(d, k)
        assert 128 <= bn <= 4096


def test_pick_block_n_bf16_half_width_stream():
    """dtype_bytes=2 budgets the bf16 streaming blocks: the half-width point
    tile can only grow the pick, never shrink it (the fp32 norms block and
    accumulators are precision-independent)."""
    assert ops.pick_block_n(2, 8, dtype_bytes=2) == 4096
    for d, k in ((64, 256), (512, 1024), (4096, 256), (8192, 512)):
        bf16 = ops.pick_block_n(d, k, dtype_bytes=2)
        fp32 = ops.pick_block_n(d, k)
        assert bf16 >= fp32, (d, k, bf16, fp32)
    # at least one big-d shape must actually benefit from the half width
    assert any(ops.pick_block_n(d, 256, dtype_bytes=2)
               > ops.pick_block_n(d, 256) for d in (2048, 4096, 8192))


# ---------------------------------------------------------------------------
# prologue kernel + bound-gated kernels (exact tile skipping)
# ---------------------------------------------------------------------------


def test_prologue_kernel_matches_jnp():
    """The fused prologue kernel's norms are BITWISE the jnp row norms (the
    reference/fused backends' cache), and the tile geometry + per-point
    center distances match the pure model tightly."""
    pts, _, _ = _mk(1000, 5, 1, jnp.float32, seed=7)
    norms, centers, radii, center_d = seed_prologue_pallas(pts, block_n=256,
                                                           interpret=True)
    cache = bounds.prologue(pts, 256)
    np.testing.assert_array_equal(np.asarray(norms), np.asarray(cache.norms))
    np.testing.assert_allclose(np.asarray(centers), np.asarray(cache.centers),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(radii), np.asarray(cache.radii),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(center_d),
                               np.asarray(cache.center_d),
                               rtol=1e-6, atol=1e-7)
    assert center_d.shape == (1000,)
    # every point sits inside its tile ball
    tile_r = np.repeat(np.asarray(radii), 256)[:1000]
    assert (np.asarray(center_d) <= tile_r + 1e-6).all()


def _gated_setup(n=1000, d=3, block_n=128, seed=0):
    pts, _, md = _mk(n, d, 1, jnp.float32, seed=seed)
    nrm = ops.point_norms(pts)
    grid = -(-n // block_n)
    pp0 = jnp.zeros((grid,), jnp.float32)
    tm0 = jnp.full((grid,), jnp.inf, jnp.float32)
    return pts, md, nrm, grid, pp0, tm0


def _no_prune_fine(n, grid):
    """center_d/dc/margin that keep the per-point seeding gate silent
    (dc = 0 -> lower bound 0 -> never clears a positive min_d2)."""
    return (jnp.zeros((n,), jnp.float32), jnp.zeros((grid,), jnp.float32),
            jnp.zeros((grid,), jnp.float32))


@pytest.mark.parametrize("n,block_n", [(1000, 128), (512, 128), (100, 128)])
def test_gated_all_active_bitwise_equals_plain(n, block_n):
    """With every tile active the gated kernel IS the plain kernel, bitwise
    (same md, same partials), plus the per-tile max bound state."""
    pts, md, nrm, grid, pp0, tm0 = _gated_setup(n=n, block_n=block_n)
    cents = jax.random.normal(jax.random.PRNGKey(5), (1, pts.shape[1]))
    active = jnp.ones((grid,), bool)
    cd, dc, mg = _no_prune_fine(n, grid)
    g_md, g_p, g_tm, pruned, skipped = ops.distance_min_update_gated(
        pts, cents, md, nrm, cd, dc, mg, pp0, tm0, active, block_n=block_n)
    p_md, p_p = ops.distance_min_update(pts, cents, md, norms=nrm,
                                        block_n=block_n)
    np.testing.assert_array_equal(np.asarray(g_md), np.asarray(p_md))
    np.testing.assert_array_equal(np.asarray(g_p), np.asarray(p_p))
    np.testing.assert_array_equal(
        np.asarray(g_tm), np.asarray(bounds.tile_reduce_max(p_md, block_n)))
    assert int(skipped) == 0
    assert float(jnp.sum(pruned)) == 0.0


def test_gated_skipping_is_bitwise_exact():
    """Acceptance pin: a round that skips tiles AND prunes points produces
    BITWISE the plain kernel's outputs — min_d2, partials AND tile_max —
    because both bound levels are sufficient conditions (skipped tiles alias
    their prior state; pruned points' min-update is a provable no-op)."""
    pts, md0, nrm, grid, pp0, tm0 = _gated_setup(n=1024, d=2, block_n=128)
    cache = bounds.RoundCache(nrm, *seed_prologue_pallas(
        pts, block_n=128, interpret=True)[1:])
    # round 1: everything active, fills the bound state
    c1 = pts[3:4]
    a1, dc1, mg1 = bounds.seed_gate(c1, cache, tm0)
    md1, p1, tm1, pr1, _ = ops.distance_min_update_gated(
        pts, c1, md0, nrm, cache.center_d, dc1, mg1, pp0, tm0, a1,
        block_n=128)
    # round 2: a far-away centroid — most tiles provably cannot change
    c2 = jnp.full((1, 2), 50.0)
    a2, dc2, mg2 = bounds.seed_gate(c2, cache, tm1)
    assert int(jnp.sum(a2)) < grid, "probe must actually skip tiles"
    md2, p2, tm2, pr2, skipped = ops.distance_min_update_gated(
        pts, c2, md1, nrm, cache.center_d, dc2, mg2, p1, tm1, a2,
        block_n=128)
    # one tile is always computed (compact_ids' write-back guard)
    assert int(skipped) == grid - max(int(jnp.sum(a2)), 1) > 0
    # the fine level fires inside the force-computed tile: every point of a
    # skippable tile is individually prunable against the far centroid
    assert float(jnp.sum(pr2)) > 0
    want_md, want_p = ops.distance_min_update(pts, c2, md1, norms=nrm,
                                              block_n=128)
    np.testing.assert_array_equal(np.asarray(md2), np.asarray(want_md))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(want_p))
    np.testing.assert_array_equal(
        np.asarray(tm2), np.asarray(bounds.tile_reduce_max(want_md, 128)))


def test_gated_batched_matches_single():
    """vmap over the gated wrapper lowers to the batch-grid gated kernel and
    row b is bitwise the single-problem gated kernel on problem b (including
    per-problem skip/prune counts)."""
    B, n, d, bn = 3, 512, 2, 128
    keys = jax.random.split(jax.random.PRNGKey(8), 3)
    pts = jax.random.normal(keys[0], (B, n, d))
    cents = jnp.stack([jnp.full((1, d), 30.0 * b) for b in range(B)])
    md = jnp.abs(jax.random.normal(keys[1], (B, n))) * 2
    nrm = jax.vmap(ops.point_norms)(pts)
    grid = -(-n // bn)
    pp = jnp.abs(jax.random.normal(keys[2], (B, grid)))
    tm = jnp.abs(jax.random.normal(jax.random.fold_in(keys[2], 1), (B, grid)))
    cd = jnp.abs(jax.random.normal(jax.random.fold_in(keys[2], 2), (B, n)))
    dc = jnp.abs(jax.random.normal(jax.random.fold_in(keys[2], 3),
                                   (B, grid))) * 3
    mg = jnp.full((B, grid), 1e-4)
    # a mix of active/inactive tiles per problem
    active = jnp.arange(grid)[None, :] % (jnp.arange(B)[:, None] + 2) == 0
    out = jax.vmap(lambda p, c, m, nr, b_cd, b_dc, b_mg, a, b_pp, b_tm:
                   ops.distance_min_update_gated(p, c, m, nr, b_cd, b_dc,
                                                 b_mg, b_pp, b_tm, a,
                                                 block_n=bn))(
        pts, cents, md, nrm, cd, dc, mg, active, pp, tm)
    for b in range(B):
        s = ops.distance_min_update_gated(pts[b], cents[b], md[b], nrm[b],
                                          cd[b], dc[b], mg[b], pp[b], tm[b],
                                          active[b], block_n=bn)
        for o, w in zip(out[:4], s[:4]):
            np.testing.assert_array_equal(np.asarray(o[b]), np.asarray(w))
        assert int(out[4][b]) == int(s[4])


# ---------------------------------------------------------------------------
# argmin tie-breaking parity (duplicate centroids, e.g. after empty='reseed')
# ---------------------------------------------------------------------------


def test_argmin_tie_break_parity_across_paths():
    """Duplicate centroids produce exact distance ties; every assignment path
    (oracle, pallas single, pallas batch-grid, blocked-XLA) must resolve them
    to the SAME (lowest) index — tile skipping and reseeding both rely on
    deterministic ties."""
    from repro.core.engine import (FusedBackend, PallasBackend,
                                   ReferenceBackend, assign_blocked)
    pts, _, _ = _mk(600, 4, 1, jnp.float32, seed=13)
    base = jax.random.normal(jax.random.PRNGKey(14), (3, 4))
    cents = jnp.concatenate([base, base[1:2], base[0:1]])  # dup rows 1 and 0
    a_ref, _, _, _ = ref.lloyd_assign_ref(pts, cents)
    assert int(jnp.max(a_ref)) <= 2, "ties must resolve to the first copy"
    a_pal, _, _, _ = ops.lloyd_assign(pts, cents)
    np.testing.assert_array_equal(np.asarray(a_pal), np.asarray(a_ref))
    a_blk, _ = assign_blocked(pts, cents)
    np.testing.assert_array_equal(np.asarray(a_blk), np.asarray(a_ref))
    bpts = jnp.stack([pts, pts[::-1]])
    bc = jnp.stack([cents, cents])
    a_b, _, _, _ = jax.vmap(lambda p, c: ops.lloyd_assign(p, c))(bpts, bc)
    np.testing.assert_array_equal(np.asarray(a_b[0]), np.asarray(a_ref))
    for be in (ReferenceBackend(), FusedBackend(), PallasBackend()):
        rnd = be.assign_update(pts, cents, None)
        np.testing.assert_array_equal(np.asarray(rnd.assignment),
                                      np.asarray(a_ref), err_msg=be.name)
        # the tiled (bounded-fit) path must break ties identically
        cache = be.prologue(pts, m=cents.shape[0], with_bounds=False)
        tiled = be.assign_update(pts, cents, None, cache=cache)
        np.testing.assert_array_equal(np.asarray(tiled.assignment),
                                      np.asarray(a_ref), err_msg=be.name)


def test_kernel_inside_seeding_loop():
    """Pallas round used end-to-end inside kmeanspp gives identical seeds."""
    from repro.core import kmeanspp
    pts, _, _ = _mk(512, 4, 1, jnp.float32, seed=11)
    key = jax.random.PRNGKey(5)
    ref_res = kmeanspp(key, pts, 7, variant="fused", sampler="cdf")
    pal_res = kmeanspp(key, pts, 7, variant="pallas_fused", sampler="cdf")
    np.testing.assert_array_equal(np.asarray(ref_res.indices),
                                  np.asarray(pal_res.indices))


# ---------------------------------------------------------------------------
# flash attention kernel (memory-term §Perf kernel)
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, Skv, H, KH, hd, causal, window, cap, bq, bk)
    (2, 128, 128, 4, 2, 32, True, 0, 0.0, 64, 64),
    (1, 200, 200, 4, 4, 16, True, 0, 0.0, 64, 64),      # ragged seq
    (2, 64, 256, 8, 2, 32, False, 0, 0.0, 64, 128),     # cross attention
    (1, 256, 256, 2, 1, 64, True, 64, 50.0, 64, 64),    # window + softcap
    (1, 96, 96, 2, 2, 128, True, 0, 0.0, 32, 32),       # hd 128
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    from repro.kernels.flash_attention import flash_attention
    B, Sq, Skv, H, KH, hd, causal, window, cap, bq, bk = case
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(keys[1], (B, Skv, KH, hd), dtype)
    v = jax.random.normal(keys[2], (B, Skv, KH, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_decode_offset():
    """q_offset (chunked prefill / decode) masks exactly like the oracle."""
    from repro.kernels.flash_attention import flash_attention
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (1, 32, 2, 32))
    k = jax.random.normal(keys[1], (1, 128, 2, 32))
    v = jax.random.normal(keys[2], (1, 128, 2, 32))
    got = flash_attention(q, k, v, causal=True, q_offset=64,
                          block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# tile_cap: the movement-tightening kernel (ISSUE 9)
# ---------------------------------------------------------------------------

TILE_CAP_SHAPES = [  # (n_tiles, d, m) — tiny, ragged-ish, multi-pending
    (1, 2, 1),
    (4, 2, 1),
    (16, 8, 4),
    (7, 3, 8),
    (33, 16, 2),
]


@pytest.mark.parametrize("n_tiles,d,m", TILE_CAP_SHAPES)
def test_tile_cap_matches_ref(n_tiles, d, m):
    from repro.kernels.kmeans_distance import tile_cap_pallas
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    centers = jax.random.normal(keys[0], (n_tiles, d), jnp.float32)
    radii = jnp.abs(jax.random.normal(keys[1], (n_tiles,), jnp.float32))
    pending = jax.random.normal(keys[2], (m, d), jnp.float32)
    for count in {0, 1, m}:
        cnt = jnp.asarray(count, jnp.int32)
        got = tile_cap_pallas(centers, radii, pending, cnt, interpret=True)
        want = ref.tile_cap_ref(centers, radii, pending, cnt)
        if count == 0:
            assert np.all(np.isinf(np.asarray(got))), \
                "count==0 must return +inf everywhere (no tightening)"
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_tile_cap_dominates_rows():
    """The Raff bound is an UPPER bound: cap_t >= d(x_i, pending_j)^2 for
    every row i inside tile t's ball and every pending j < count — the
    property that keeps the tightened envelope valid (and the draw exact)."""
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    n, d, bn, m = 512, 4, 128, 3
    pts = jax.random.normal(k1, (n, d), jnp.float32) * 3
    pending = jax.random.normal(k2, (m, d), jnp.float32)
    n_tiles = n // bn
    xt = pts.reshape(n_tiles, bn, d)
    centers = xt.mean(axis=1)
    radii = jnp.sqrt(jnp.max(jnp.sum((xt - centers[:, None, :]) ** 2, axis=-1),
                             axis=1))
    cap = ref.tile_cap_ref(centers, radii, pending, jnp.asarray(m, jnp.int32))
    d2 = jnp.min(jnp.sum((pts[:, None, :] - pending[None, :, :]) ** 2,
                         axis=-1), axis=1).reshape(n_tiles, bn)
    slack = np.asarray(cap)[:, None] - np.asarray(d2)
    assert np.all(slack >= -1e-3), f"cap violated by {slack.min()}"


def test_tile_cap_op_vmaps_via_ref():
    """ops.tile_cap under vmap (the batched seeding path) routes to the ref
    twin and matches a per-problem loop of the kernel."""
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    B, n_tiles, d, m = 3, 8, 4, 2
    centers = jax.random.normal(keys[0], (B, n_tiles, d), jnp.float32)
    radii = jnp.abs(jax.random.normal(keys[1], (B, n_tiles), jnp.float32))
    pending = jax.random.normal(keys[2], (B, m, d), jnp.float32)
    counts = jnp.asarray([0, 1, 2], jnp.int32)
    got = jax.vmap(lambda c, r, p, ct: ops.tile_cap(c, r, p, ct,
                                                    interpret=True))(
        centers, radii, pending, counts)
    for b in range(B):
        want = ref.tile_cap_ref(centers[b], radii[b], pending[b], counts[b])
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
