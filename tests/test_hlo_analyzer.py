"""Unit tests for the scan-aware HLO analyzer (roofline measurement layer)."""
import textwrap

from repro.roofline.hlo import analyze, scan_trip_counts

_FAKE_HLO = textwrap.dedent("""\
    HloModule jit_step, num_partitions=16

    %add.1 (x: f32[], y: f32[]) -> f32[] {
      %x = f32[] parameter(0)
      %y = f32[] parameter(1)
      ROOT %a = f32[] add(%x, %y)
    }

    %fused_computation.1 (p0: f32[128,256]) -> f32[128,256] {
      %p0 = f32[128,256]{1,0} parameter(0)
      ROOT %m = f32[128,256]{1,0} multiply(%p0, %p0)
    }

    %body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
      %p = (s32[], f32[128,256]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
      %w = f32[256,256]{1,0} constant({...})
      %d = f32[128,256]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[128,256]{1,0} all-reduce(%d), channel_id=1, replica_groups=[4,4]<=[16], use_global_device_ids=true, to_apply=%add.1
      ROOT %t = (s32[], f32[128,256]{1,0}) tuple(%i, %ar)
    }

    %cond.1 (p: (s32[], f32[128,256])) -> pred[] {
      %p = (s32[], f32[128,256]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %k = s32[] constant(10)
      ROOT %lt = pred[] compare(%i, %k), direction=LT
    }

    ENTRY %main (a: f32[128,256]) -> f32[128,256] {
      %a = f32[128,256]{1,0} parameter(0)
      %f = f32[128,256]{1,0} fusion(%a), kind=kLoop, calls=%fused_computation.1
      %t0 = (s32[], f32[128,256]{1,0}) tuple(%c, %f)
      %w = (s32[], f32[128,256]{1,0}) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"},"known_init_step":{"init":"0","step":"1"}}
      %ag = f32[512,256]{1,0} all-gather(%a), channel_id=2, replica_groups={{0,1,2,3}}, dimensions={0}
      ROOT %r = f32[128,256]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_trip_counts_from_backend_config():
    trips = scan_trip_counts(_FAKE_HLO)
    assert trips == {"body.1": 10}


def test_flops_scaled_by_trip_count():
    r = analyze(_FAKE_HLO)
    # dot: 2 * 128*256 * 256 = 16.78M flops, x10 loop iterations
    assert r["flops"] == 2 * 128 * 256 * 256 * 10


def test_collectives_counted_with_groups():
    r = analyze(_FAKE_HLO)
    by = r["collectives"]["by_kind"]
    ar_bytes = 128 * 256 * 4
    # all-reduce in the loop: ring 2*(g-1)/g with g=4, times 10 trips
    assert abs(by["all-reduce"] - 2 * ar_bytes * 3 / 4 * 10) < 1
    # all-gather at top level: result 512x256 f32, (g-1)/g with g=4
    ag = 512 * 256 * 4
    assert abs(by["all-gather"] - ag * 3 / 4) < 1
    assert r["n_devices"] == 16


def test_bytes_include_fusion_roundtrip():
    r = analyze(_FAKE_HLO)
    # fusion reads a (128*256*4) and writes same: >= 2x tensor bytes
    assert r["bytes"] >= 2 * 128 * 256 * 4


def test_analyzer_on_real_compiled_module():
    """End-to-end: jit a scan on 1 device, check trip-count scaling."""
    import jax
    import jax.numpy as jnp

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=12)
        return h

    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    r = analyze(hlo)
    expect = 2 * 64 * 64 * 64 * 12
    assert abs(r["flops"] - expect) / expect < 0.01, r["flops"]
