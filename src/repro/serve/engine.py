"""Batched serving engine: continuous-batching-style prefill + decode.

A deliberately compact production pattern:
  * fixed decode batch of ``max_batch`` slots, each slot = one request;
  * prefill fills a slot's KV cache (padded to ``max_len``), decode advances
    ALL active slots one token per step (the jitted hot path);
  * finished slots (EOS / max_tokens) are refilled from the queue —
    continuous batching without paged attention (the cache is dense;
    PQ compression via serve/kvquant.py is the long-context variant).

Single-slot caches are padded/stacked along batch; per-slot position masking
keeps ragged requests independent. greedy or temperature sampling.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import ArchConfig
from repro.models.registry import get_model


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    max_new_tokens: int = 64
    eos_id: int = -1              # -1: never stops early
    temperature: float = 0.0      # 0 = greedy
    group_timeout: Optional[float] = None  # wall-clock seconds per decode
    #                                        group; None = unbounded. On
    #                                        expiry the group stops decoding
    #                                        and still-active slots return
    #                                        their partial completions.


@dataclasses.dataclass
class RequestError(Exception):
    """A malformed request, rejected per-slot: returned IN PLACE of that
    prompt's completion so one bad request cannot crash (or stall) the
    whole batch. Callers pattern-match with ``isinstance(r, RequestError)``
    — or ``raise`` it, it is a real exception."""
    reason: str
    index: int = -1

    def __post_init__(self):
        super().__init__(f"request {self.index}: {self.reason}")


class Engine:
    def __init__(self, cfg: ArchConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.model = get_model(cfg)
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=serve_cfg.max_len))

    def _sample(self, key, logits):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def _check_prompt(self, p) -> Optional[str]:
        """Reject reason for a malformed prompt, or None when servable."""
        a = np.asarray(p)
        if a.ndim != 1:
            return f"prompt must be a 1-D token array, got shape {a.shape}"
        if a.size == 0:
            return "empty prompt"
        if not np.issubdtype(a.dtype, np.integer):
            return f"prompt dtype {a.dtype} is not an integer token dtype"
        if a.size > self.scfg.max_len:
            return (f"prompt length {a.size} exceeds max_len "
                    f"{self.scfg.max_len}")
        return None

    def generate(self, prompts: list[np.ndarray], *, seed: int = 0
                 ) -> list[Any]:
        """Generate completions for a list of token prompts (np int32 1-D).
        Prompts are grouped into batches of max_batch; each group shares a
        jitted prefill (padded to the longest prompt) + decode loop.

        Failure semantics: a malformed prompt (empty, non-1-D, float
        tokens, longer than ``max_len``) gets a ``RequestError`` in its
        output slot — the other requests in the call are still served, in
        order. With ``ServeConfig.group_timeout`` set, each group's decode
        loop additionally stops at the wall-clock deadline and returns the
        partial completions instead of holding the queue."""
        out: list[Any] = [None] * len(prompts)
        valid: list[int] = []
        for idx, p in enumerate(prompts):
            reason = self._check_prompt(p)
            if reason is None:
                valid.append(idx)
            else:
                out[idx] = RequestError(reason, index=idx)
        key = jax.random.PRNGKey(seed)
        B = self.scfg.max_batch
        for i in range(0, len(valid), B):
            grp = valid[i:i + B]
            done = self._generate_group([prompts[j] for j in grp], key)
            for j, g in zip(grp, done):
                out[j] = g
            key = jax.random.fold_in(key, i)
        return out

    def _generate_group(self, group, key):
        n = len(group)
        lens = [len(p) for p in group]
        L = max(lens)
        toks = np.zeros((n, L), np.int32)
        for j, p in enumerate(group):
            toks[j, L - len(p):] = p          # left-pad: last position = last token
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})

        # wall-clock budget for THIS group's decode loop: one stuck/huge
        # group must not hold the rest of the queue; expired slots simply
        # return the tokens generated so far.
        deadline = (None if self.scfg.group_timeout is None
                    else time.monotonic() + self.scfg.group_timeout)
        done = np.zeros(n, bool)
        gen: list[list[int]] = [[] for _ in range(n)]
        tok = self._sample(key, logits)
        for step in range(self.scfg.max_new_tokens):
            t_np = np.asarray(jax.device_get(tok))
            for j in range(n):
                if not done[j]:
                    gen[j].append(int(t_np[j]))
                    if t_np[j] == self.scfg.eos_id:
                        done[j] = True
            if done.all():
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
            logits, cache = self._decode(self.params, tok[:, None], cache)
            key = jax.random.fold_in(key, step)
            tok = self._sample(key, logits)
        return [np.asarray(g, np.int32) for g in gen]
