"""Batched IVF vector search over a trained k-means model.

The clustering stack's output IS an inverted-file index: the fitted
centroids are a coarse quantizer, each cluster an inverted list. This
module closes that loop for serving:

**Build** (`IvfIndex.build`) runs the fused ``kmeans``, permutes rows with
`data.ordering.label_sort_order` so every inverted list is one contiguous
run of tiles, and records everything the scan kernels stream: per-list
``starts``/``counts`` offsets, the per-tile ball summaries the seed
prologue already computes (`core.bounds.prologue`), the (nlist, n_tiles)
list->tile coverage matrix, and — optionally — PQ residual codes through
`serve.kvquant` (codebook over ``x - centroid[label]``, plus the
reconstructed-row norms and balls the ADC path needs).

**Query** (`IvfIndex.search`) is one batched pass per call:

1. *routing* — exact top-``nprobe`` centroids per query, two-level: a
   coarse super-centroid pass bounds the nprobe-th centroid distance from
   ball geometry alone (``tau_ub`` = the max upper bound of the smallest
   ub-sorted prefix covering >= nprobe centroids), then the exact rerank
   runs only over supers whose lower bound clears ``tau_ub`` — the same
   prefix-cover argument as the seeding hierarchy, so routing is EXACT,
   never approximate;
2. *gated cluster-local scan* — the Pallas kernels in
   ``kernels/ivf_scan.py``: per-query compacted probed-tile maps steered
   through scalar prefetch (tiles outside the probed lists are never
   fetched), a per-tile kth-distance triangle-inequality gate
   (`core.bounds.ivf_gate_skip` — a bitwise value-noop), and an fp32
   lexicographic top-k merge carried across tiles (`core.topk`);
3. *scoring* — ``mode="exact"`` streams raw rows (bitwise equal to
   `IvfIndex.exhaustive` at ``nprobe == nlist``); ``mode="adc"`` streams
   uint8 PQ codes and scores via per-query LUT + routing-dot contraction
   (exact distances to the reconstructed rows, ~``n_sub/(4d)`` of the
   exact path's bytes).

Every search revalidates the list offsets against the stored layout before
trusting them (`CorruptedStateError` on mismatch — wrong neighbors are
silent, a poisoned index must never return), and reports the per-query
telemetry counters `core.telemetry.check_ivf_counters` pins.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds
from repro.core.engine import ClusterEngine
from repro.core.guards import (CorruptedStateError, InvalidInputError,
                               check_policy, guard_points)
from repro.core.topk import IDX_SENTINEL
from repro.data.ordering import label_sort_order
from repro.kernels import ops as kops
from repro.serve import kvquant

__all__ = ["IvfIndex", "IvfPq", "SearchResult", "default_nprobe"]


class IvfPq(NamedTuple):
    """PQ residual storage riding on an IvfIndex (``mode="adc"`` inputs).

    ``u`` and the balls are computed over the RECONSTRUCTED rows
    ``x_hat = centroid[label] + decode(code)`` — ADC scores are exact
    distances to x_hat, so the same triangle-inequality gate stays a
    value-noop on the ADC path."""
    codes: jax.Array            # (n, n_sub) uint8, sorted row order
    codebook: kvquant.PQCodebook
    u: jax.Array                # (n,) fp32 ||x_hat||^2
    centers: jax.Array          # (n_tiles, d) balls over x_hat
    radii: jax.Array            # (n_tiles,)


class SearchResult(NamedTuple):
    """Batched search output + the per-query telemetry counters."""
    indices: jax.Array          # (Q, k) int32 CALLER row ids (IDX_SENTINEL
    #                             pads when k > n)
    dists: jax.Array            # (Q, k) fp32 squared distances
    probed_lists: jax.Array     # (Q,) int32 non-empty lists routed to
    probed_tiles: jax.Array     # (Q,) int32 tiles the scan visited
    gate_skipped: jax.Array     # (Q,) int32 visited tiles the gate skipped


class IvfIndex(NamedTuple):
    """A trained k-means model packaged as an inverted-file index.

    Rows are stored label-sorted (``points == caller_points[perm]``);
    kernel row ids map back through ``perm``. ``layout="none"`` keeps the
    caller's row order (perm = identity) — the benchmark contrast showing
    WHY list-contiguous layouts matter — while ``starts``/``counts`` stay
    the would-be offsets so the corruption check has one invariant."""
    points: jax.Array           # (n, d) fp32, sorted rows
    norms: jax.Array            # (n,) fp32 cached ||x||^2
    centers: jax.Array          # (n_tiles, d) tile ball centers
    radii: jax.Array            # (n_tiles,) tile ball radii
    labels: jax.Array           # (n,) int32 list id per sorted row
    perm: jax.Array             # (n,) int32 sorted -> caller row map
    starts: jax.Array           # (nlist,) int32 list boundary offsets
    counts: jax.Array           # (nlist,) int32 list sizes
    centroids: jax.Array        # (nlist, d) fp32 coarse quantizer
    centroid_norms: jax.Array   # (nlist,) fp32
    super_centers: jax.Array    # (n_super, d) routing hierarchy
    super_radii: jax.Array      # (n_super,)
    super_sizes: jax.Array      # (n_super,) int32 real centroids per super
    list_tiles: jax.Array       # (nlist, n_tiles) bool coverage matrix
    block_n: int                # scan tile height (static)
    backend: str                # default scan backend
    pq: Optional[IvfPq] = None  # ADC storage (build(pq_nsub=...))

    # -- derived statics ---------------------------------------------------
    @property
    def n(self) -> int:
        return self.points.shape[0]

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def n_tiles(self) -> int:
        return self.centers.shape[0]

    # -- build -------------------------------------------------------------
    @classmethod
    def build(cls, points, nlist: int, *, engine: ClusterEngine | None = None,
              key: jax.Array | None = None, block_n: int | None = None,
              layout: str = "label", pq_nsub: int | None = None,
              max_iters: int = 25, validate: str = "raise") -> "IvfIndex":
        """Cluster ``points`` into ``nlist`` inverted lists and package the
        scan inputs. ``layout="label"`` (default) sorts rows so each list is
        a contiguous tile run; ``layout="none"`` keeps caller order (the
        scattered-layout baseline). ``pq_nsub`` adds PQ residual storage
        for ``mode="adc"`` (d % pq_nsub == 0)."""
        check_policy(validate)
        if layout not in ("label", "none"):
            raise InvalidInputError(
                f"unknown layout {layout!r}; expected 'label' or 'none'")
        points = guard_points(points, validate, name="points")
        pts = jnp.asarray(points, jnp.float32)
        n, d = pts.shape
        if not 0 < nlist <= n:
            raise InvalidInputError(
                f"need 0 < nlist <= n, got nlist={nlist}, n={n}")
        eng = ClusterEngine("fused", tune="cache") if engine is None \
            else engine
        if key is None:
            key = jax.random.PRNGKey(0)
        res = eng.kmeans(key, pts, nlist, max_iters=max_iters)
        centroids = jnp.asarray(res.centroids, jnp.float32)
        labels = jnp.asarray(res.assignment, jnp.int32)

        if layout == "label":
            perm, _, starts, counts = label_sort_order(
                labels, nlist=nlist, return_offsets=True)
        else:
            perm = jnp.arange(n, dtype=jnp.int32)
            counts = jnp.bincount(labels, length=nlist).astype(jnp.int32)
            starts = (jnp.cumsum(counts) - counts).astype(jnp.int32)
        spts = pts[perm]
        slab = labels[perm]

        if block_n is None:
            # the tile is the probe granularity: aim for ~4 tiles per
            # inverted list (pow2, >= 128) so nprobe of nlist lists maps to
            # ~nprobe/nlist of the tiles — capped by the VMEM-validated
            # round-kernel pick
            cap = kops.choose_block_n(n, d, 1, batched=True)
            tgt = 1 << max(7, (n // (4 * nlist)).bit_length() - 1)
            block_n = max(128, min(cap, tgt))
        rc = bounds.prologue(spts, block_n)
        n_tiles = rc.centers.shape[0]

        # routing hierarchy: pow2 groups of ~sqrt(nlist) consecutive
        # centroids; ball stats over REAL members only (masked pad)
        g = _super_group_size(int(nlist))
        n_sup = -(-nlist // g)
        cpad = jnp.pad(centroids, ((0, n_sup * g - nlist), (0, 0)))
        member = (jnp.arange(n_sup * g) < nlist).reshape(n_sup, g)
        sizes = member.sum(axis=1).astype(jnp.int32)
        grp = cpad.reshape(n_sup, g, d)
        sup_c = (jnp.where(member[:, :, None], grp, 0.0).sum(axis=1)
                 / jnp.maximum(sizes, 1)[:, None])
        sup_d2 = jnp.sum((grp - sup_c[:, None, :]) ** 2, axis=-1)
        sup_r = jnp.sqrt(jnp.max(jnp.where(member, sup_d2, 0.0), axis=1))

        tile_of_row = (jnp.arange(n, dtype=jnp.int32)
                       // jnp.int32(block_n))
        list_tiles = jnp.zeros((nlist, n_tiles), bool) \
            .at[slab, tile_of_row].max(True)

        pq = None
        if pq_nsub is not None:
            resid = spts - centroids[slab]
            cb = kvquant.build_codebook(
                jax.random.fold_in(key, 1), resid, n_sub=pq_nsub,
                engine=engine, validate=validate)
            codes = kvquant.encode(resid, cb, validate=validate)
            xhat = (kvquant.decode(codes, cb).astype(jnp.float32)
                    + centroids[slab])
            arc = bounds.prologue(xhat, block_n)
            pq = IvfPq(codes, cb, arc.norms, arc.centers, arc.radii)

        backend = getattr(eng.backend, "name", "fused")
        return cls(points=spts, norms=rc.norms, centers=rc.centers,
                   radii=rc.radii, labels=slab, perm=perm, starts=starts,
                   counts=counts, centroids=centroids,
                   centroid_norms=bounds.point_norms(centroids),
                   super_centers=sup_c, super_radii=sup_r,
                   super_sizes=sizes, list_tiles=list_tiles,
                   block_n=int(block_n), backend=backend, pq=pq)

    # -- query -------------------------------------------------------------
    def search(self, queries, k: int, nprobe: int | None = None, *,
               mode: str = "exact", gate: bool = True,
               backend: str | None = None,
               validate: str = "raise") -> SearchResult:
        """Batched top-``k``: route each query to its top-``nprobe``
        centroids, scan only those lists' tiles. ``nprobe=None`` consults
        the tune cache's advisory column (:func:`default_nprobe`).
        ``mode="adc"`` scores against the PQ reconstruction (requires
        ``build(pq_nsub=...)``); ``gate=False`` disables the (value-noop)
        kth-distance tile gate, for benchmarking its traffic effect.
        Raises `CorruptedStateError` if the stored list offsets disagree
        with the layout — never returns silently-wrong neighbors."""
        check_policy(validate)
        if mode not in ("exact", "adc"):
            raise InvalidInputError(
                f"unknown mode {mode!r}; expected 'exact' or 'adc'")
        if mode == "adc" and self.pq is None:
            raise InvalidInputError(
                "mode='adc' needs PQ storage: build(pq_nsub=...)")
        self._check_offsets()
        queries = guard_points(queries, validate, name="queries")
        q = jnp.asarray(queries, jnp.float32)
        if q.ndim != 2 or q.shape[1] != self.points.shape[1]:
            raise InvalidInputError(
                f"queries shape {q.shape} does not match index dimension "
                f"{self.points.shape[1]}")
        if not 0 < k:
            raise InvalidInputError(f"need k >= 1, got k={k}")
        if nprobe is None:
            nprobe = default_nprobe(self.n, self.nlist,
                                    self.points.shape[1])
        nprobe = max(1, min(int(nprobe), self.nlist))

        probed, qdots = _route(q, self.centroids, self.centroid_norms,
                               self.super_centers, self.super_radii,
                               self.super_sizes, nprobe=nprobe)
        tiles = (probed.astype(jnp.float32)
                 @ self.list_tiles.astype(jnp.float32)) > 0.0
        ids, n_active = jax.vmap(bounds.compact_ids)(tiles)
        probed_lists = jnp.sum(probed & (self.counts > 0)[None, :],
                               axis=1).astype(jnp.int32)

        be = self.backend if backend is None else backend
        dists, rows, skipped = self._scan(q, qdots, ids, n_active, k=int(k),
                                          mode=mode, gate=gate, backend=be)
        return SearchResult(indices=_map_rows(rows, self.perm),
                            dists=dists, probed_lists=probed_lists,
                            probed_tiles=n_active.astype(jnp.int32),
                            gate_skipped=skipped)

    def exhaustive(self, queries, k: int) -> tuple[jax.Array, jax.Array]:
        """Brute-force batched top-k over every row — the ground truth
        ``search`` at ``nprobe == nlist`` equals BITWISE (same cached
        norms, same per-row dot arithmetic, same lexicographic tie-break
        over sorted-row ids). Returns (indices, dists) in caller ids."""
        from repro.kernels.ref import ivf_bruteforce_topk

        q = jnp.asarray(queries, jnp.float32)
        dists, rows = ivf_bruteforce_topk(q, self.points, self.norms,
                                          k=min(int(k), self.n))
        if k > self.n:      # pad like the scan's sentinel slots
            pad = int(k) - self.n
            dists = jnp.pad(dists, ((0, 0), (0, pad)),
                            constant_values=jnp.inf)
            rows = jnp.pad(rows, ((0, 0), (0, pad)),
                           constant_values=IDX_SENTINEL)
        return _map_rows(rows, self.perm), dists

    # -- internals ---------------------------------------------------------
    def _scan(self, q, qdots, ids, n_active, *, k: int, mode: str,
              gate: bool, backend: str):
        """Dispatch the gated scan, walking the kernel fallback chain on
        KernelFailureError (same degradation policy as the engine)."""
        from repro.core.guards import KernelFailureError
        from repro.kernels import ref as kref

        kk = min(k, self.n)
        be = backend
        while True:
            try:
                if mode == "exact":
                    if be == "pallas":
                        out = kops.ivf_scan(
                            q, self.points, self.norms, self.centers,
                            self.radii, ids, n_active, k=kk,
                            block_n=self.block_n, gate=gate)
                    else:
                        kops._check_forced()
                        out = kref.ivf_scan_ref(
                            q, self.points, self.norms, self.centers,
                            self.radii, ids, n_active, k=kk,
                            block_n=self.block_n, gate=gate)
                else:
                    lut = _adc_lut(q, self.pq.codebook)
                    if be == "pallas":
                        out = kops.ivf_adc_scan(
                            q, lut, qdots, self.pq.codes, self.labels,
                            self.pq.u, self.pq.centers, self.pq.radii,
                            ids, n_active, k=kk, block_n=self.block_n,
                            gate=gate)
                    else:
                        kops._check_forced()
                        out = kref.ivf_adc_scan_ref(
                            q, lut, qdots, self.pq.codes, self.labels,
                            self.pq.u, self.pq.centers, self.pq.radii,
                            ids, n_active, k=kk, block_n=self.block_n,
                            gate=gate)
                break
            except KernelFailureError:
                be = kops.FALLBACK_CHAIN.get(be)
                if be is None:
                    raise
        dists, rows, skipped = out
        if k > self.n:      # sentinel-pad the impossible slots
            pad = k - self.n
            dists = jnp.pad(dists, ((0, 0), (0, pad)),
                            constant_values=jnp.inf)
            rows = jnp.pad(rows, ((0, 0), (0, pad)),
                           constant_values=IDX_SENTINEL)
        return dists, rows, skipped

    def _check_offsets(self) -> None:
        """Host-side offset revalidation, ALWAYS on (independent of the
        ``validate`` policy): the scan trusts ``starts``/``counts`` to
        describe the stored layout, and a poisoned offset table would
        return silently-wrong neighbors — the one failure mode serving can
        never have. Cost: one (nlist,)-sized numpy pass per search."""
        starts = np.asarray(self.starts)
        counts = np.asarray(self.counts)
        nlist = self.nlist
        if starts.shape != (nlist,) or counts.shape != (nlist,):
            raise CorruptedStateError(
                f"ivf index offsets have shapes {starts.shape}/"
                f"{counts.shape}, expected ({nlist},): rebuild the index")
        if (counts < 0).any() or (starts < 0).any():
            raise CorruptedStateError(
                "ivf index offsets contain negative entries: rebuild the "
                "index")
        if int(counts.sum()) != self.n:
            raise CorruptedStateError(
                f"ivf list sizes sum to {int(counts.sum())} != n={self.n}: "
                "rebuild the index")
        expect = np.cumsum(counts) - counts
        if not np.array_equal(starts, expect):
            raise CorruptedStateError(
                "ivf list starts disagree with exclusive-cumsum(counts): "
                "rebuild the index")


def default_nprobe(n: int, nlist: int, d: int) -> int:
    """The ``nprobe=None`` resolution: the tune cache's advisory ``nprobe``
    column for this (n, k=nlist, d) shape under the "ivf" backend key, else
    the nlist/8 heuristic (`tune.search._advisory`'s rationale)."""
    from repro import tune

    rec = tune.resolve(tune.TuneCache(None), n=int(n), k=int(nlist),
                       d=int(d), backend="ivf", dtype="float32",
                       mode="cache")
    if rec is not None and int(rec.nprobe) > 0:
        return min(int(rec.nprobe), int(nlist))
    return max(1, int(nlist) // 8)


def _super_group_size(nlist: int) -> int:
    """Centroids per super group: the pow2 nearest ~sqrt(nlist). Build and
    routing must agree on this — ``sup_of_list`` in :func:`_route` is
    reconstructed from it, and any mismatch maps centroids to the wrong
    super ball, breaking the exact-routing guarantee."""
    return 1 << ((int(nlist - 1).bit_length() + 1) // 2) if nlist > 1 else 1


@functools.partial(jax.jit, static_argnames=("nprobe",))
def _route(q, centroids, centroid_norms, sup_c, sup_r, sup_sizes, *,
           nprobe: int):
    """Exact top-``nprobe`` centroid routing.

    Coarse pass: per (query, super) bounds ``lb = max(d - R, 0)^2`` /
    ``ub = (d + R)^2`` from the super ball; ``tau_ub`` = the largest ub of
    the smallest ub-sorted prefix covering >= nprobe centroids, so the
    nprobe-th best centroid distance is <= tau_ub and every top-nprobe
    centroid's super satisfies ``lb <= tau_ub``. The exact rerank masks
    non-surviving supers' centroids to +inf — by that argument it can never
    mask a true top-nprobe centroid, so routing equals the full-rerank
    result exactly (fp slack mirrors `core.bounds`' gate margins: at
    ``nprobe == nlist`` every super survives and routing IS the full
    rerank). Returns (probed (Q, nlist) bool, qdots (Q, nlist) fp32 — the
    routing dots the ADC path reuses)."""
    nlist = centroids.shape[0]
    g = _super_group_size(nlist)
    qn = jnp.sum(q * q, axis=1)                                # (Q,)

    sc2 = jnp.sum(sup_c * sup_c, axis=1)
    sd2 = jnp.maximum(qn[:, None] - 2.0 * (q @ sup_c.T) + sc2[None, :], 0.0)
    sd = jnp.sqrt(sd2)                                         # (Q, n_sup)
    lb = jnp.maximum(sd - sup_r[None, :], 0.0) ** 2
    ub = (sd + sup_r[None, :]) ** 2
    order = jnp.argsort(ub, axis=1)
    csum = jnp.cumsum(jnp.take_along_axis(
        jnp.broadcast_to(sup_sizes[None, :], ub.shape), order, axis=1),
        axis=1)
    pos = jnp.argmax(csum >= nprobe, axis=1)
    tau_ub = jnp.take_along_axis(jnp.take_along_axis(ub, order, axis=1),
                                 pos[:, None], axis=1)[:, 0]
    margin = bounds._ABS * (jnp.sqrt(sc2)[None, :] + sup_r[None, :]
                            + jnp.sqrt(qn)[:, None]) ** 2
    survive = lb <= tau_ub[:, None] * (1.0 + bounds._REL) + margin

    qdots = q @ centroids.T                                    # (Q, nlist)
    cd2 = jnp.maximum(qn[:, None] - 2.0 * qdots
                      + centroid_norms[None, :], 0.0)
    sup_of_list = jnp.arange(nlist, dtype=jnp.int32) // jnp.int32(g)
    cd2m = jnp.where(survive[:, sup_of_list], cd2, jnp.inf)
    lid = jnp.broadcast_to(jnp.arange(nlist, dtype=jnp.int32)[None, :],
                           cd2m.shape)
    _, sel = jax.vmap(lambda v, i: jax.lax.sort((v, i), num_keys=2))(
        cd2m, lid)
    probed = jnp.zeros((q.shape[0], nlist), bool) \
        .at[jnp.arange(q.shape[0])[:, None], sel[:, :nprobe]].set(True)
    return probed, qdots


@jax.jit
def _adc_lut(q, cb: kvquant.PQCodebook):
    """Per-query inner-product LUT over the residual codebook:
    ``lut[q, s, c] = q_s . codebook[s, c]`` — the one table ADC scoring
    contracts every streamed code against."""
    n_sub, n_codes, dsub = cb.centroids.shape
    qsub = q.reshape(q.shape[0], n_sub, dsub)
    return jnp.einsum("qsd,scd->qsc", qsub,
                      cb.centroids.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


@jax.jit
def _map_rows(rows, perm):
    """Sorted-layout kernel row ids -> caller row ids, sentinel-preserving."""
    n = perm.shape[0]
    safe = jnp.clip(rows, 0, n - 1)
    return jnp.where(rows == IDX_SENTINEL, IDX_SENTINEL, perm[safe])
