"""repro.serve — batched serving engine + k-means++ KV product quantization."""
from repro.serve.engine import Engine, RequestError, ServeConfig
from repro.serve import kvquant

__all__ = ["Engine", "RequestError", "ServeConfig", "kvquant"]
