"""repro.serve — batched serving engine, k-means++ KV product quantization,
and IVF vector search over trained models."""
from repro.serve.engine import Engine, RequestError, ServeConfig
from repro.serve import kvquant
from repro.serve.ivf import IvfIndex, IvfPq, SearchResult, default_nprobe

__all__ = ["Engine", "RequestError", "ServeConfig", "kvquant",
           "IvfIndex", "IvfPq", "SearchResult", "default_nprobe"]
