"""KV-cache product quantization via distributed k-means++ (paper integration #1).

Long-context decode is HBM-bound: a 512k-token bf16 KV cache for a 7B model
is ~100s of GB. PQ compresses each key/value vector into ``n_sub`` uint8
codes + a small codebook:

    head_dim d split into n_sub sub-vectors of d/n_sub
    each sub-space clustered to 256 centroids (k-means++ seeded — the
    paper's phase — then a few Lloyd iterations)
    vector -> n_sub uint8 codes;   compression = d*2 / (n_sub bytes)

The codebooks are built from a sample of the live cache (per layer, per k/v),
amortized over many decode steps. Attention against a PQ cache decodes
per-block via codebook lookup — here we provide exact decompression +
quality metrics; the fused decode-attention-over-codes kernel is the TPU
production path sketched in kernels/ (lookup = one-hot matmul on the MXU).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kmeanspp import kmeanspp, pairwise_d2
from repro.core.lloyd import lloyd


class PQCodebook(NamedTuple):
    centroids: jax.Array      # (n_sub, 256, d_sub)


class PQCache(NamedTuple):
    codes: jax.Array          # (..., n_sub) uint8
    codebook: PQCodebook


def build_codebook(key: jax.Array, vectors: jax.Array, *, n_sub: int,
                   n_codes: int = 256, lloyd_iters: int = 10,
                   sample: int = 16384) -> PQCodebook:
    """vectors (N, d) -> PQ codebook. d % n_sub == 0."""
    N, d = vectors.shape
    assert d % n_sub == 0, (d, n_sub)
    dsub = d // n_sub
    take = min(sample, N)
    stride = max(N // take, 1)
    sub = vectors[::stride][:take].reshape(take, n_sub, dsub)

    def fit(ks, xs):
        k_eff = min(n_codes, xs.shape[0])
        seeds = kmeanspp(ks, xs, k_eff, variant="fused").centroids
        res = lloyd(xs, seeds, max_iters=lloyd_iters)
        cents = res.centroids
        if k_eff < n_codes:     # pad (tiny caches in tests)
            cents = jnp.pad(cents, ((0, n_codes - k_eff), (0, 0)))
        return cents

    keys = jax.random.split(key, n_sub)
    cents = jnp.stack([fit(keys[s], sub[:, s]) for s in range(n_sub)])
    return PQCodebook(cents.astype(jnp.float32))


def encode(vectors: jax.Array, cb: PQCodebook) -> jax.Array:
    """(..., d) -> (..., n_sub) uint8 codes."""
    n_sub, n_codes, dsub = cb.centroids.shape
    lead = vectors.shape[:-1]
    x = vectors.reshape(-1, n_sub, dsub).astype(jnp.float32)

    def one(s):
        d2 = pairwise_d2(x[:, s], cb.centroids[s])
        return jnp.argmin(d2, axis=1).astype(jnp.uint8)

    codes = jnp.stack([one(s) for s in range(n_sub)], axis=-1)
    return codes.reshape(*lead, n_sub)


def decode(codes: jax.Array, cb: PQCodebook) -> jax.Array:
    """(..., n_sub) uint8 -> (..., d) reconstruction."""
    n_sub, n_codes, dsub = cb.centroids.shape
    lead = codes.shape[:-1]
    c = codes.reshape(-1, n_sub)
    parts = [cb.centroids[s][c[:, s]] for s in range(n_sub)]
    return jnp.concatenate(parts, axis=-1).reshape(*lead, n_sub * dsub)


def compress_kv(key: jax.Array, kv: jax.Array, *, n_sub: int = 8,
                lloyd_iters: int = 10) -> PQCache:
    """kv (..., d) -> PQ cache (codes + codebook). Compression vs bf16 is
    (d * 2) / n_sub, e.g. head_dim 128, n_sub 8 -> 32x."""
    flat = kv.reshape(-1, kv.shape[-1])
    cb = build_codebook(key, flat, n_sub=n_sub, lloyd_iters=lloyd_iters)
    return PQCache(encode(kv, cb), cb)


def reconstruction_error(kv: jax.Array, pq: PQCache) -> jax.Array:
    """Relative MSE of the PQ roundtrip (quality metric for EXPERIMENTS.md)."""
    rec = decode(pq.codes, pq.codebook).astype(jnp.float32)
    x = kv.astype(jnp.float32)
    return jnp.mean((rec - x) ** 2) / jnp.maximum(jnp.mean(x ** 2), 1e-12)


def compression_ratio(kv: jax.Array, pq: PQCache) -> float:
    raw = kv.size * jnp.dtype(kv.dtype).itemsize
    comp = pq.codes.size + pq.codebook.centroids.size * 4
    return float(raw) / float(comp)


# ---------------------------------------------------------------------------
# transformer-cache integration (kernels/pq_decode.py consumes this layout)
# ---------------------------------------------------------------------------

def compress_transformer_cache(key: jax.Array, cache: dict, *,
                               n_sub: int = 16, lloyd_iters: int = 6) -> dict:
    """Convert a dense transformer KV cache {"k","v": (L,B,S,KH,hd), "pos"}
    into the PQ layout the flash-decode-over-codes kernel reads:

        {"k_codes","v_codes": (L,B,S,KH,n_sub) uint8,
         "k_cb","v_cb":      (L,KH,n_sub,256,hd/n_sub) f32, "pos"}

    Codebooks are fit per (layer, kv-head) with k-means++ seeding — the
    paper's phase; a production server re-fits them every ~1k decode steps
    from a cache sample (amortized to noise)."""
    out = {"pos": cache["pos"]}
    for name in ("k", "v"):
        kv = cache[name]
        L, B, S, KH, hd = kv.shape
        cbs = []
        codes = []
        for l in range(L):
            cb_h, code_h = [], []
            for h in range(KH):
                flat = kv[l, :, :, h].reshape(-1, hd)
                cb = build_codebook(jax.random.fold_in(key, l * 64 + h),
                                    flat, n_sub=n_sub,
                                    lloyd_iters=lloyd_iters)
                cb_h.append(cb.centroids)
                code_h.append(encode(kv[l, :, :, h], cb))
            cbs.append(jnp.stack(cb_h))
            codes.append(jnp.stack(code_h, axis=2))
        out[f"{name}_codes"] = jnp.stack(codes).astype(jnp.uint8)
        out[f"{name}_cb"] = jnp.stack(cbs)
    return out


def cache_bytes(cache: dict) -> int:
    return sum(int(x.size * jnp.dtype(x.dtype).itemsize)
               for x in jax.tree.leaves(cache))
