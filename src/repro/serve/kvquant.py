"""KV-cache product quantization via distributed k-means++ (paper integration #1).

Long-context decode is HBM-bound: a 512k-token bf16 KV cache for a 7B model
is ~100s of GB. PQ compresses each key/value vector into ``n_sub`` uint8
codes + a small codebook:

    head_dim d split into n_sub sub-vectors of d/n_sub
    each sub-space clustered to 256 centroids (k-means++ seeded — the
    paper's phase — then a few Lloyd iterations)
    vector -> n_sub uint8 codes;   compression = d*2 / (n_sub bytes)

The codebooks are built from a sample of the live cache (per layer, per k/v),
amortized over many decode steps. Attention against a PQ cache decodes
per-block via codebook lookup — here we provide exact decompression +
quality metrics; the fused decode-attention-over-codes kernel is the TPU
production path sketched in kernels/ (lookup = one-hot matmul on the MXU).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import ClusterEngine
from repro.core.guards import InvalidInputError, check_policy, guard_points
from repro.core.kmeanspp import pairwise_d2


class PQCodebook(NamedTuple):
    centroids: jax.Array      # (n_sub, 256, d_sub)


def _check_codebook(cb: PQCodebook, *, what: str) -> None:
    """Shape abuse is never sanitizable (core.guards policy): an empty or
    malformed codebook raises typed regardless of the validate mode."""
    c = jnp.asarray(cb.centroids)
    if c.ndim != 3 or c.size == 0:
        raise InvalidInputError(
            f"{what}: codebook centroids must be a non-empty "
            f"(n_sub, n_codes, d_sub) array, got shape {c.shape}")


def _check_subspaces(d: int, n_sub: int, *, what: str) -> None:
    if n_sub < 1 or d % n_sub != 0:
        raise InvalidInputError(
            f"{what}: d={d} must split into n_sub={n_sub} equal sub-vectors "
            f"(d % n_sub == 0, n_sub >= 1)")


class PQCache(NamedTuple):
    codes: jax.Array          # (..., n_sub) uint8
    codebook: PQCodebook


# codebook builds hit the same (take, 256, d_sub) shape for every layer of
# every model — the repeated-shape workload where a persisted autotune
# cache amortizes best. tune="cache" is lookup-only: zero measurement on a
# cold cache (pure heuristics, bitwise the pre-tune behavior), tuned
# geometry for free once a warmed cache is shipped via $REPRO_TUNE_CACHE
# (see docs/engine.md "Autotuning").
_DEFAULT_ENGINE = ClusterEngine("fused", tune="cache")


def _fit_codebooks(key: jax.Array, problems: jax.Array, *, n_codes: int,
                   lloyd_iters: int, engine: Optional[ClusterEngine],
                   order=None) -> jax.Array:
    """problems (B, take, dsub) -> (B, n_codes, dsub) centroids.

    ONE `ClusterEngine.kmeans_batched` call clusters every sub-space problem
    in the batch — a single compiled seeding sweep + a single batched Lloyd,
    instead of the old per-sub-space Python loop of kmeanspp+lloyd calls. On
    the pallas backend this runs the batch-grid kernels. ``order`` (e.g.
    'morton') feeds each sub-space problem to the kernels in a tile-coherent
    row layout so the bound gates can prune; the engine inverts the
    permutation internally, so codebooks are unaffected."""
    eng = _DEFAULT_ENGINE if engine is None else engine
    B, take, _ = problems.shape
    k_eff = min(n_codes, take)
    keys = jax.random.split(key, B)
    res = eng.kmeans_batched(keys, problems, k_eff, max_iters=lloyd_iters,
                             order=order)
    cents = res.centroids
    if k_eff < n_codes:         # pad (tiny caches in tests)
        cents = jnp.pad(cents, ((0, 0), (0, n_codes - k_eff), (0, 0)))
    return cents.astype(jnp.float32)


def build_codebook(key: jax.Array, vectors: jax.Array, *, n_sub: int,
                   n_codes: int = 256, lloyd_iters: int = 10,
                   sample: int = 16384,
                   engine: Optional[ClusterEngine] = None,
                   order=None, validate: str = "raise") -> PQCodebook:
    """vectors (N, d) -> PQ codebook. d % n_sub == 0. The n_sub sub-space
    clusterings run as one batched multi-problem sweep through `engine`
    (default: the fused ClusterEngine; pass ClusterEngine('pallas') for the
    batch-grid kernels). ``order='morton'`` reorders each sub-space sample
    into a tile-coherent layout for the bound-gated kernels.

    ``validate`` is the core.guards entry policy: 'raise' (typed
    InvalidInputError on non-finite rows), 'sanitize' (zero offending rows
    — a NaN training row would otherwise poison whole sub-space codebooks),
    or 'off'. Shape abuse (d % n_sub != 0) always raises typed."""
    check_policy(validate)
    N, d = vectors.shape
    _check_subspaces(d, n_sub, what="build_codebook")
    vectors = guard_points(vectors, validate, name="vectors")
    dsub = d // n_sub
    take = min(sample, N)
    stride = max(N // take, 1)
    sub = vectors[::stride][:take].reshape(take, n_sub, dsub)
    cents = _fit_codebooks(key, jnp.moveaxis(sub, 1, 0), n_codes=n_codes,
                           lloyd_iters=lloyd_iters, engine=engine,
                           order=order)
    return PQCodebook(cents)


def encode(vectors: jax.Array, cb: PQCodebook, *,
           validate: str = "raise") -> jax.Array:
    """(..., d) -> (..., n_sub) uint8 codes. ``validate`` guards the entry
    (core.guards policy): non-finite rows raise/zero/pass; an empty codebook
    or a d that does not match the codebook always raises typed."""
    check_policy(validate)
    _check_codebook(cb, what="encode")
    n_sub, n_codes, dsub = cb.centroids.shape
    if vectors.shape[-1] != n_sub * dsub:
        raise InvalidInputError(
            f"encode: vectors dimension {vectors.shape[-1]} != codebook's "
            f"n_sub * d_sub = {n_sub * dsub}")
    vectors = guard_points(vectors, validate, name="vectors")
    lead = vectors.shape[:-1]
    x = vectors.reshape(-1, n_sub, dsub).astype(jnp.float32)

    def one(s):
        d2 = pairwise_d2(x[:, s], cb.centroids[s])
        return jnp.argmin(d2, axis=1).astype(jnp.uint8)

    codes = jnp.stack([one(s) for s in range(n_sub)], axis=-1)
    return codes.reshape(*lead, n_sub)


def decode(codes: jax.Array, cb: PQCodebook, *,
           validate: str = "raise") -> jax.Array:
    """(..., n_sub) uint8 -> (..., d) reconstruction. ``validate`` is
    accepted for entry-policy symmetry with :func:`encode` (codes are
    integers, so there are no non-finite rows to guard); an empty codebook
    or a code width that does not match it always raises typed."""
    check_policy(validate)
    _check_codebook(cb, what="decode")
    n_sub, n_codes, dsub = cb.centroids.shape
    if codes.shape[-1] != n_sub:
        raise InvalidInputError(
            f"decode: codes width {codes.shape[-1]} != codebook's "
            f"n_sub = {n_sub}")
    lead = codes.shape[:-1]
    c = codes.reshape(-1, n_sub)
    parts = [cb.centroids[s][c[:, s]] for s in range(n_sub)]
    return jnp.concatenate(parts, axis=-1).reshape(*lead, n_sub * dsub)


def compress_kv(key: jax.Array, kv: jax.Array, *, n_sub: int = 8,
                lloyd_iters: int = 10,
                engine: Optional[ClusterEngine] = None,
                order=None) -> PQCache:
    """kv (..., d) -> PQ cache (codes + codebook). Compression vs bf16 is
    (d * 2) / n_sub, e.g. head_dim 128, n_sub 8 -> 32x."""
    flat = kv.reshape(-1, kv.shape[-1])
    cb = build_codebook(key, flat, n_sub=n_sub, lloyd_iters=lloyd_iters,
                        engine=engine, order=order)
    return PQCache(encode(kv, cb), cb)


def reconstruction_error(kv: jax.Array, pq: PQCache) -> jax.Array:
    """Relative MSE of the PQ roundtrip (quality metric for EXPERIMENTS.md)."""
    rec = decode(pq.codes, pq.codebook).astype(jnp.float32)
    x = kv.astype(jnp.float32)
    return jnp.mean((rec - x) ** 2) / jnp.maximum(jnp.mean(x ** 2), 1e-12)


def compression_ratio(kv: jax.Array, pq: PQCache) -> float:
    raw = kv.size * jnp.dtype(kv.dtype).itemsize
    comp = pq.codes.size + pq.codebook.centroids.size * 4
    return float(raw) / float(comp)


# ---------------------------------------------------------------------------
# transformer-cache integration (kernels/pq_decode.py consumes this layout)
# ---------------------------------------------------------------------------

def compress_transformer_cache(key: jax.Array, cache: dict, *,
                               n_sub: int = 16, lloyd_iters: int = 6,
                               sample: int = 16384,
                               engine: Optional[ClusterEngine] = None,
                               order=None) -> dict:
    """Convert a dense transformer KV cache {"k","v": (L,B,S,KH,hd), "pos"}
    into the PQ layout the flash-decode-over-codes kernel reads:

        {"k_codes","v_codes": (L,B,S,KH,n_sub) uint8,
         "k_cb","v_cb":      (L,KH,n_sub,256,hd/n_sub) f32, "pos"}

    Codebooks are fit per (layer, kv-head) with k-means++ seeding — the
    paper's phase; a production server re-fits them every ~1k decode steps
    from a cache sample (amortized to noise). ALL L*KH*n_sub sub-space
    clusterings for a tensor run as ONE `ClusterEngine.kmeans_batched` sweep
    (the multi-tenant batch-grid path), not an L*KH Python loop."""
    out = {"pos": cache["pos"]}
    for i, name in enumerate(("k", "v")):
        kv = cache[name]
        L, B, S, KH, hd = kv.shape
        assert hd % n_sub == 0, (hd, n_sub)
        dsub = hd // n_sub
        # (L,B,S,KH,hd) -> (L*KH, B*S, hd): one row of problems per
        # (layer, kv-head), sub-sampled like build_codebook
        groups = jnp.moveaxis(kv, 3, 1).reshape(L * KH, B * S, hd)
        take = min(sample, B * S)
        stride = max((B * S) // take, 1)
        sub = groups[:, ::stride][:, :take]
        # (L*KH, take, hd) -> (L*KH*n_sub, take, dsub)
        problems = jnp.moveaxis(
            sub.reshape(L * KH, take, n_sub, dsub), 2, 1
        ).reshape(L * KH * n_sub, take, dsub)
        cents = _fit_codebooks(jax.random.fold_in(key, i), problems,
                               n_codes=256, lloyd_iters=lloyd_iters,
                               engine=engine, order=order)
        cbs = cents.reshape(L, KH, n_sub, 256, dsub)
        codes = jnp.stack([
            jnp.stack([encode(kv[l, :, :, h], PQCodebook(cbs[l, h]))
                       for h in range(KH)], axis=2)
            for l in range(L)])
        out[f"{name}_codes"] = codes.astype(jnp.uint8)
        out[f"{name}_cb"] = cbs
    return out


def cache_bytes(cache: dict) -> int:
    return sum(int(x.size * jnp.dtype(x.dtype).itemsize)
               for x in jax.tree.leaves(cache))
