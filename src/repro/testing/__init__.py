"""repro.testing — deterministic fault injection for robustness tests.

Never imported by production code paths: ``repro.core.engine`` duck-types
the ``FaultSpec`` it receives (any hashable object with ``.kind`` and
``.round`` works as the static ``_fault`` argument), so the core package
has no dependency on this one.
"""
from repro.testing.faults import (FaultSpec, corrupt_list_offsets,
                                  flaky_read_fn, force_kernel_failure,
                                  kill_prefetch)

__all__ = ["FaultSpec", "corrupt_list_offsets", "flaky_read_fn",
           "force_kernel_failure", "kill_prefetch"]
