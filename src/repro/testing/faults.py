"""Deterministic, seedable fault injection for the clustering stack.

Every fault in the matrix is reproducible: faults fire at a named round /
step, not at a random time, so a failing robustness test replays exactly.
The injectors cover the layers a real deployment loses sleep over:

  * ``FaultSpec`` — traced-compute corruption, threaded into the engine's
    jitted loops as a STATIC argument (it is a frozen, hashable dataclass).
    Kinds:
      - ``nan_tile``      seed loop: NaN one tile's D2 output at ``round``
      - ``nan_state``     seed/fit loop: NaN the carried partials (bound
                          state poisoning) at ``round``
      - ``zero_counts``   fit loop: halve a round's psum'd sums/counts
                          (a lost shard contribution) at ``round``
      - ``neg_envelope``  rejection seeding: corrupt the stale proposal
                          envelope with a negative partial at ``round``
      - ``stale_super``   rejection seeding: NaN every tile partial backing
                          the LAST super-tile at ``round`` — a torn coarse
                          aggregate. The coarse-to-fine proposal state is
                          DERIVED from the partials each round, so the
                          corrupt super is healed by the same prefix refold
                          as ``neg_envelope`` (bitwise replay)
  * ``force_kernel_failure`` — context manager that makes every public
    kernel wrapper in ``repro.kernels.ops`` raise ``KernelFailureError``
    at trace time (a stand-in for a Pallas compile/launch failure),
    exercising the engine's backend fallback chain.
  * ``flaky_read_fn`` / ``kill_prefetch`` — host-side pipeline faults:
    transient reader failures (retry path) and a dead prefetch thread
    (typed ``PipelineError`` path).
  * ``corrupt_list_offsets`` — serving-index state corruption: returns an
    ``IvfIndex`` whose ``starts``/``counts`` offset table disagrees with
    the stored layout (torn write / stale checkpoint half-merge). The
    index's always-on offset revalidation must catch it: ``search`` raises
    typed ``CorruptedStateError``, never silently-wrong neighbors.

The contract the fault matrix asserts (tests/test_faults.py): every fault
either RECOVERS BITWISE (guarded loops heal and the final result equals a
never-corrupted run's) or raises a typed ``ClusteringError`` subclass.
Never a silent wrong answer.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable, Iterator

from repro.kernels import ops

SEED_FAULTS = ("nan_tile", "nan_state")
FIT_FAULTS = ("zero_counts", "nan_state")
REJECTION_FAULTS = ("neg_envelope", "stale_super")
ALL_FAULTS = ("nan_tile", "nan_state", "zero_counts", "neg_envelope",
              "stale_super")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injected fault: ``kind`` names the corruption, ``round`` the
    loop iteration (seed round / fit iteration / rejection draw) it fires
    at. Frozen + hashable so it can ride the jit static-argument path."""
    kind: str
    round: int = 1

    def __post_init__(self):
        if self.kind not in ALL_FAULTS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {ALL_FAULTS}")


@contextlib.contextmanager
def force_kernel_failure(reason: str = "injected kernel failure"
                         ) -> Iterator[None]:
    """Inside this context every ``repro.kernels.ops`` wrapper raises
    ``KernelFailureError(reason)`` — the deterministic stand-in for a
    Pallas compile/launch failure. The engine reacts by walking its
    backend fallback chain (pallas -> fused -> reference)."""
    prev = ops._FORCED_FAILURE
    ops._FORCED_FAILURE = str(reason)
    try:
        yield
    finally:
        ops._FORCED_FAILURE = prev


def flaky_read_fn(read_fn: Callable[[int], dict], *, fail_steps: dict
                  ) -> Callable[[int], dict]:
    """Wrap a pipeline ``read_fn`` so step ``s`` fails its first
    ``fail_steps[s]`` calls (transient storage flake), then succeeds.
    Thread-safe; mutates ``fail_steps`` down to zero in place so the
    caller can assert how many retries actually happened."""
    lock = threading.Lock()

    def flaky(s: int) -> dict:
        with lock:
            left = fail_steps.get(s, 0)
            if left > 0:
                fail_steps[s] = left - 1
                raise IOError(f"injected transient read failure at step {s}")
        return read_fn(s)

    return flaky


IVF_OFFSET_FAULTS = ("shifted_start", "short_count", "negative_count")


def corrupt_list_offsets(index, *, kind: str = "shifted_start"):
    """Return a copy of an ``serve.ivf.IvfIndex`` with a corrupted offset
    table (the rest of the index untouched — exactly the torn-state shape
    a half-applied checkpoint restore produces):

      - ``shifted_start``   one list's start drifts off the cumsum layout
      - ``short_count``     one list under-reports its size (sum != n)
      - ``negative_count``  one count goes negative

    Every kind violates an invariant ``IvfIndex.search`` revalidates before
    trusting the table, so the corrupted index must raise typed
    ``CorruptedStateError`` on search — never return silently-wrong
    neighbors."""
    import jax.numpy as jnp

    if kind not in IVF_OFFSET_FAULTS:
        raise ValueError(
            f"unknown offset fault {kind!r}; one of {IVF_OFFSET_FAULTS}")
    if kind == "shifted_start":
        return index._replace(starts=index.starts.at[-1].add(1))
    if kind == "short_count":
        return index._replace(counts=index.counts.at[0].add(-1))
    return index._replace(
        counts=index.counts.at[0].set(jnp.int32(-1)))


def kill_prefetch(pipeline) -> None:
    """Kill a DataPipeline's prefetch thread mid-stream: the next batch the
    worker tries to read raises, so the consumer's next ``__next__`` gets a
    typed ``PipelineError`` instead of hanging on a dead queue."""
    def _dead(s: int) -> dict:
        raise RuntimeError(f"injected prefetch death at step {s}")

    pipeline.read_fn = _dead
    pipeline.retries = 1  # no point backing off a deliberate kill
