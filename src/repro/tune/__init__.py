"""Measured cost-model autotuner (ISSUE 8).

Per ``(n, k, d, backend, dtype)`` problem shape, pick the round-kernel
geometry (``block_n``, ``tiles_per_super``) plus the advisory knobs
(spatial ``order``, stream ``precision``, sampler choice) that minimize
the measured — or, when wall-clock is unavailable, the modelled — cost of
one seeding/assignment round, and persist the winner in a schema-versioned
JSON cache so later calls (and later processes) reuse it with zero extra
measurement. ``ClusterEngine(tune="auto"|"cache")`` is the only user
surface; provenance comes back as the ``TuneRecord`` on results.
"""
from repro.tune.cache import (SCHEMA_VERSION, TuneCache, TuneRecord,
                              backend_key)
from repro.tune.search import resolve, search

__all__ = ["SCHEMA_VERSION", "TuneCache", "TuneRecord", "backend_key",
           "resolve", "search"]
