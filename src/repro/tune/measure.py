"""Measurement harness: wall-clock the round primitives, or model them.

Three cost probes, cheapest-first, all counted by the module-level
``CALLS`` counter (the warm-cache test pins that a cache hit performs
ZERO of them):

* ``model_seed_round_bytes`` / ``model_fit_round_bytes`` — the analytic
  HBM models of ``benchmarks/round_traffic.py``, parameterized by the
  candidate geometry (``block_n``, ``tps``). These are the search's inner
  loop: pure arithmetic, thousands of candidates per millisecond.
* ``hlo_round_cost`` — compile (never execute) one assignment round via
  ``roofline.hlo.analyze_jit`` and read the per-op byte/FLOP accounting
  out of the optimized HLO. This is the "measured" side of the
  predicted-vs-measured gap when wall-clock is unavailable (interpret
  mode / CPU CI).
* ``measure_round_ms`` — deterministic warmup + median-of-trials wall
  clock of a real ``seed``/``fit`` round. Only meaningful on real
  accelerator hardware: ``wallclock_available()`` gates it, and callers
  get ``nan`` elsewhere.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import bounds as bnd

# every cost-probe evaluation (model candidate, HLO compile, wall-clock
# trial set) bumps this — tests pin "warm cache => zero extra calls"
CALLS = 0


def _count() -> None:
    global CALLS
    CALLS += 1


def wallclock_available() -> bool:
    """Wall-clock numbers are only trustworthy when the kernels actually
    run compiled on the accelerator; Pallas interpret mode (CPU CI) and
    host-only backends time the interpreter, not the machine."""
    return jax.default_backend() == "tpu"


def median_ms(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (ms) of ``fn(*args)`` with deterministic warmup."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return 1000.0 * times[len(times) // 2]


# ---------------------------------------------------------------------------
# analytic models (the single source of truth — benchmarks/round_traffic.py
# delegates here so the benchmark columns and the tuner score can't drift)
# ---------------------------------------------------------------------------


def model_seed_round_bytes(n: int, d: int, *, block_n: int,
                           skip_rate: float = 0.0,
                           dtype_bytes: int = 4) -> int:
    """Modelled HBM bytes of ONE gated seeding round at tile height
    ``block_n``: per active tile the kernel streams the point block
    (stream dtype) + the fp32 cached-norms block, reads+writes the fp32
    min_d2 block and writes the two fp32 bound-state scalars; skipped
    tiles move nothing."""
    n_tiles = -(-n // block_n)
    active = round(n_tiles * (1.0 - skip_rate))
    per_tile = block_n * (d * dtype_bytes + 4 + 2 * 4) + 2 * 4
    return active * per_tile


def model_fit_round_bytes(n: int, d: int, k: int, *, block_n: int,
                          tps=None, skip_rate: float = 0.0,
                          dtype_bytes: int = 4) -> int:
    """Modelled HBM bytes of ONE gated assignment iteration at tile height
    ``block_n`` with super-tile fan-in ``tps`` (None = heuristic): per
    active tile the kernel streams points + norms, carries the
    label/min_d2/point_lb triple in and out, amortizes the per-SUPER
    cluster sums/counts block over its tps tiles, and writes the
    partial/gap/pruned scalars. Skipped tiles move nothing — larger tps
    means fewer super slots hence fewer accumulator bytes, at the price of
    coarser skip granularity (a super skips only when ALL its tiles do)."""
    n_tiles = -(-n // block_n)
    tps = bnd.tiles_per_super(n_tiles, tps)
    active = round(n_tiles * (1.0 - skip_rate))
    per_tile = (block_n * (d * dtype_bytes + 4)     # points + norms in
                + 2 * block_n * (4 + 4 + 4)         # assign/md/lb i/o
                + 4 * (k * d + k) / tps             # super sums/counts,
                                                    # amortized over tps
                + 3 * 4)                            # partial/gap/pruned
    return round(active * per_tile)


def model_round_cost(n: int, k: int, d: int, *, block_n: int, tps=None,
                     dtype_bytes: int = 4) -> float:
    """The search's scalar objective: modelled bytes of one seeding round
    plus one assignment iteration at skip_rate=0 (the gate's skips are
    data-dependent; geometry is tuned for the worst case where every tile
    is active). One ``CALLS`` tick per candidate."""
    _count()
    return (model_seed_round_bytes(n, d, block_n=block_n,
                                   dtype_bytes=dtype_bytes)
            + model_fit_round_bytes(n, d, k, block_n=block_n, tps=tps,
                                    dtype_bytes=dtype_bytes))


# ---------------------------------------------------------------------------
# compiled-HLO and wall-clock probes
# ---------------------------------------------------------------------------


def _probe_problem(n: int, d: int, k: int):
    """Deterministic synthetic rows for the probes (content is irrelevant
    to byte counts; wall clock only needs realistic shapes)."""
    key = jax.random.PRNGKey(0)
    pts = jax.random.normal(key, (n, d), jnp.float32)
    cents = pts[:k]
    return pts, cents


def hlo_round_cost(n: int, k: int, d: int, *, backend=None) -> dict:
    """Compile one ungated assignment round on the given backend (default
    fused — cheap to compile anywhere) and account the optimized HLO:
    ``{"flops", "bytes"}``. Nothing executes."""
    from repro.core.engine import FusedBackend
    from repro.roofline.hlo import analyze_jit

    _count()
    be = FusedBackend() if backend is None else backend
    pts, cents = _probe_problem(n, d, k)
    cache = be.prologue(pts, k, with_bounds=False)

    def one_round(p, c):
        rnd = be.assign_update(p, c, None, cache.norms, cache=cache)
        return rnd.sums, rnd.counts

    res = analyze_jit(one_round, pts, cents)
    return {"flops": res["flops"], "bytes": res["bytes"]}


def measure_round_ms(n: int, k: int, d: int, *, backend=None,
                     warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock (ms) of one compiled assignment round, ``nan``
    when wall-clock is meaningless (see ``wallclock_available``)."""
    if not wallclock_available():
        return float("nan")
    from repro.core.engine import FusedBackend

    _count()
    be = FusedBackend() if backend is None else backend
    pts, cents = _probe_problem(n, d, k)
    cache = be.prologue(pts, k, with_bounds=False)
    fn = jax.jit(lambda p, c: be.assign_update(p, c, None, cache.norms,
                                               cache=cache).sums)
    return median_ms(fn, pts, cents, warmup=warmup, iters=iters)
