"""Search layer: sweep the candidate grid, score, record the winner.

The grid is exactly the space the engine can legally run:

* ``block_n`` — powers of two from the 128-row floor up to the
  VMEM-validated heuristic pick of ``kernels.ops.choose_block_n`` (tuned
  blocks only ever SHRINK the heuristic, so every candidate fits the
  ``pick_block_n`` budget by construction);
* ``tps`` — powers of two from 1 to the next power of two >= n_tiles
  (``bounds.tiles_per_super`` clamps/floors anything else).

Scoring uses the cheapest probe that is trustworthy here (see
``tune.measure``): the analytic byte model for every candidate, then —
when real hardware is present — wall-clock on the winner, recorded next
to the model's prediction so ``BENCH_tune.json`` can report the
predicted-vs-measured gap. The sweep scores at skip_rate=0 (all tiles
active): skips are data-dependent, and the accumulator term the sweep
actually moves (``4*(k*d+k)/tps`` per tile) is skip-independent.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.tune import measure
from repro.tune.cache import TuneCache, TuneRecord, backend_key


def _pow2s(lo: int, hi: int) -> list[int]:
    """Powers of two in [lo, hi] (hi included after pow2-ceiling lo)."""
    out = []
    v = 1 << max(int(lo) - 1, 0).bit_length()
    while v <= hi:
        out.append(v)
        v <<= 1
    return out


def candidate_grid(n: int, k: int, d: int, *,
                   dtype_bytes: int = 4) -> list[tuple[int, int]]:
    """(block_n, tps) candidates for one shape."""
    from repro.kernels.ops import choose_block_n

    base = choose_block_n(n, d, k, batched=True)
    grid = []
    for bn in _pow2s(128, base):
        n_tiles = -(-n // bn)
        cap = 1 << max(int(n_tiles - 1).bit_length(), 0) if n_tiles > 1 else 1
        for tps in _pow2s(1, cap):
            grid.append((bn, tps))
    return grid


def _advisory(n: int, k: int, d: int) -> dict:
    """The advisory knobs (never auto-applied unless the caller opts in
    with order="auto" / sampler="auto"; precision is recorded only):

    * order  — Morton ordering recovers tile coherence (what makes the
      movement-bound gate fire) when rows arrive shuffled; the interleaved
      bits lose locality as d grows, so recommend it only at low d.
    * sampler — the rejection sampler's stale-envelope refresh goes
      sub-linear in k (ISSUE 6): worth its bookkeeping once there are
      enough seeds to amortize a refresh block over.
    * proposal — the coarse-to-fine draw (ISSUE 9) wins exactly where the
      rejection sampler does: enough seeds for pending centroids to
      accumulate between refreshes (tightening needs something pending)
      and enough tiles for the super level to amortize its extra
      searchsorted. At tiny k / tiny n_tiles the flat draw's O(n_tiles)
      read is already trivial, so recommend 'flat' there.
    * precision — the round kernels are memory-bound once the point block
      dominates the stream; bf16 halves exactly that term.
    * nprobe — IVF serving width for a model of this shape (k = nlist):
      k/8 keeps modelled scan traffic ~1/8 of a full pass while recall on
      clustered data stays high (see BENCH_ivf.json); tiny k degenerates
      to probing everything, where IVF buys nothing anyway.
    """
    return {
        "order": "morton" if d <= 8 else None,
        "sampler": "rejection" if k >= 32 else "tiled",
        "refresh_block": 8 if k >= 32 else 0,
        "proposal": "hier" if k >= 32 else "flat",
        "precision": "bf16" if d >= 8 else "fp32",
        "nprobe": max(1, k // 8),
    }


def search(n: int, k: int, d: int, *, backend: str = "fused",
           dtype: str = "float32") -> TuneRecord:
    """Sweep the grid for one shape and return the winning TuneRecord
    (``source`` = 'measured' on real hardware, else 'model')."""
    dtype_bytes = 2 if dtype in ("bfloat16", "float16") else 4
    from repro.kernels.ops import choose_block_n

    default_bn = choose_block_n(n, d, k, batched=True)
    default_cost = measure.model_round_cost(n, k, d, block_n=default_bn,
                                            tps=None,
                                            dtype_bytes=dtype_bytes)
    best, best_cost = None, math.inf
    for bn, tps in candidate_grid(n, k, d, dtype_bytes=dtype_bytes):
        cost = measure.model_round_cost(n, k, d, block_n=bn, tps=tps,
                                        dtype_bytes=dtype_bytes)
        # strict < keeps the FIRST minimal candidate; the grid is ordered
        # small->large so ties break toward the smaller (safer) geometry
        if cost < best_cost:
            best, best_cost = (bn, tps), cost
    measured_ms = (measure.measure_round_ms(n, k, d)
                   if measure.wallclock_available() else float("nan"))
    adv = _advisory(n, k, d)
    return TuneRecord(
        n=int(n), k=int(k), d=int(d), backend=backend, dtype=dtype,
        block_n=int(best[0]), tps=int(best[1]),
        order=adv["order"], precision=adv["precision"],
        sampler=adv["sampler"], refresh_block=int(adv["refresh_block"]),
        proposal=adv["proposal"], nprobe=int(adv["nprobe"]),
        source="measured" if measure.wallclock_available() else "model",
        predicted_bytes=float(best_cost),
        default_bytes=float(default_cost),
        measured_ms=float(measured_ms))


def resolve(cache: TuneCache, *, n: int, k: int, d: int, backend,
            dtype: str, mode: str) -> Optional[TuneRecord]:
    """The engine's lookup. mode='cache' is lookup-only: serve an exact
    hit, then the nearest tuned shape, else None (heuristics) — zero
    measurement either way. mode='auto' is willing to measure, so only an
    exact hit short-circuits; any other shape gets its own search, and
    the winner is persisted for every later call."""
    bk = backend_key(backend) if not isinstance(backend, str) else backend
    rec = cache.get(n, k, d, bk, dtype, nearest=(mode != "auto"))
    if rec is not None:
        return rec
    if mode != "auto":
        return None
    rec = search(n, k, d, backend=bk, dtype=dtype)
    cache.put(rec)
    cache.save()
    return rec
