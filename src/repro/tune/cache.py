"""Persisted autotune cache: schema-versioned, geometry-stamped JSON.

One file (``tune_cache.json`` under a configurable directory) holds every
tuned record this machine has measured, keyed by
``{backend}|{dtype}|n{n}|k{k}|d{d}``. The protocol mirrors the PR 7
checkpoint manager:

* **atomic writes** — serialize to ``<file>.tmp`` then ``os.replace``, so
  a crashed process never leaves a torn cache;
* **schema version** — a ``schema`` field stamped at the top; a bump
  invalidates the whole file (silently: stale tuning is a perf question,
  not a correctness one, so we fall back to the heuristics rather than
  raise);
* **geometry stamp** — each entry's key is recomputed from its record
  fields at load; an entry whose stamp disagrees with its fields (a
  hand-edited or half-merged file) is DROPPED, falling back to the
  heuristic for that shape;
* **typed corruption** — a cache file that is not valid JSON (or not a
  JSON object) raises :class:`repro.core.guards.CorruptedStateError`, the
  same vocabulary every other poisoned-state failure uses — never a bare
  ``json.JSONDecodeError`` escaping into the engine.

Lookup prefers an exact shape match, then falls back to the NEAREST tuned
shape of the same ``(backend, dtype)`` (log-space distance over
``(n, k, d)``): tuned ``block_n`` only ever *shrinks* the VMEM-validated
heuristic pick and ``tps`` is clamped by ``bounds.tiles_per_super``, so a
neighbor's record is always safe to apply, merely less optimal.

``TuneCache(None)`` reads ``$REPRO_TUNE_CACHE`` for the directory; when
that is unset too, the cache is in-memory only (one search per shape per
process, nothing persisted).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
from typing import Optional

from repro.core.guards import CorruptedStateError

SCHEMA_VERSION = 1

_ENV_DIR = "REPRO_TUNE_CACHE"
_FILE = "tune_cache.json"


@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """One tuned configuration + its provenance.

    The geometry fields (``block_n``, ``tps``) are applied by the engine
    via ``dataclasses.replace`` on the backend; the rest are advisory —
    ``order``/``sampler``/``refresh_block``/``proposal`` are consumed only
    when the caller passes ``order="auto"`` / ``sampler="auto"``, and
    ``precision`` is never auto-applied (it changes numerics; see
    docs/engine.md "Autotuning")."""

    # -- cache key ---------------------------------------------------------
    n: int
    k: int
    d: int
    backend: str
    dtype: str
    # -- tuned configuration ----------------------------------------------
    block_n: int = 0          # 0 = keep the heuristic pick
    tps: int = 0              # 0 = keep the heuristic fan-in
    order: Optional[str] = None
    precision: str = "fp32"
    sampler: str = "tiled"
    refresh_block: int = 0
    proposal: str = "hier"    # rejection proposal shape ('hier' | 'flat');
    #                           consumed, like sampler, only under
    #                           sampler="auto"
    nprobe: int = 0           # advisory IVF probe width for serving a
    #                           trained model of this shape (k = nlist);
    #                           0 = no recommendation. serve.ivf consults
    #                           it when search() is called with nprobe=None
    # -- provenance --------------------------------------------------------
    source: str = "heuristic"  # measured | model | heuristic | cache |
    #                            cache-nearest
    predicted_bytes: float = 0.0
    default_bytes: float = 0.0
    measured_ms: float = float("nan")

    def key(self) -> str:
        return record_key(self.n, self.k, self.d, self.backend, self.dtype)


def record_key(n: int, k: int, d: int, backend: str, dtype: str) -> str:
    return f"{backend}|{dtype}|n{int(n)}|k{int(k)}|d{int(d)}"


def backend_key(backend) -> str:
    """Cache-key name of an engine Backend: a mesh backend tunes its
    per-shard local compute, so it keys as ``mesh/<local>``."""
    if getattr(backend, "distributed", False):
        return f"mesh/{backend.local.name}"
    return backend.name


_FIELDS = {f.name for f in dataclasses.fields(TuneRecord)}


class TuneCache:
    """The persisted (or in-memory) record store. See the module docstring
    for the load/validate/fallback semantics."""

    def __init__(self, dir=None):
        if dir is None:
            dir = os.environ.get(_ENV_DIR) or None
        self.dir = pathlib.Path(dir) if dir is not None else None
        self.entries: dict[str, TuneRecord] = {}
        self.dropped: list[str] = []   # keys rejected by the geometry stamp
        self._load()

    @property
    def path(self) -> Optional[pathlib.Path]:
        return None if self.dir is None else self.dir / _FILE

    # -- persistence -------------------------------------------------------
    def _load(self) -> None:
        p = self.path
        if p is None or not p.exists():
            return
        try:
            raw = json.loads(p.read_text())
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CorruptedStateError(
                f"tune cache {p} is not valid JSON ({e}); delete it to "
                "re-tune from scratch") from e
        if not isinstance(raw, dict) or not isinstance(
                raw.get("entries", None), dict):
            raise CorruptedStateError(
                f"tune cache {p} has no entries mapping; delete it to "
                "re-tune from scratch")
        if raw.get("schema") != SCHEMA_VERSION:
            # a schema bump means the FIELDS changed meaning — stale tuning
            # is a perf question, so invalidate silently and re-tune
            return
        for key, fields in raw["entries"].items():
            rec = self._validate(key, fields)
            if rec is None:
                self.dropped.append(key)
            else:
                self.entries[key] = rec

    @staticmethod
    def _validate(key: str, fields) -> Optional[TuneRecord]:
        """Geometry stamp: the stored key must be recomputable from the
        stored fields, and the fields must be exactly the known set."""
        if not isinstance(fields, dict) or set(fields) != _FIELDS:
            return None
        try:
            rec = TuneRecord(**{k: (None if v is None else v)
                                for k, v in fields.items()})
            rec = dataclasses.replace(
                rec, n=int(rec.n), k=int(rec.k), d=int(rec.d),
                block_n=int(rec.block_n), tps=int(rec.tps),
                refresh_block=int(rec.refresh_block))
        except (TypeError, ValueError):
            return None
        if rec.key() != key:
            return None
        return rec

    def save(self) -> Optional[pathlib.Path]:
        """Atomic write-through (no-op for an in-memory cache)."""
        p = self.path
        if p is None:
            return None
        p.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "schema": SCHEMA_VERSION,
            "entries": {key: dataclasses.asdict(rec)
                        for key, rec in sorted(self.entries.items())},
        }
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        os.replace(tmp, p)
        return p

    # -- lookup ------------------------------------------------------------
    def put(self, rec: TuneRecord) -> None:
        self.entries[rec.key()] = rec

    def get(self, n: int, k: int, d: int, backend: str, dtype: str, *,
            nearest: bool = True) -> Optional[TuneRecord]:
        """Exact-match preferred; else the nearest tuned shape of the same
        (backend, dtype) in log-space over (n, k, d). The returned record
        keeps the DONOR shape in its key fields (honest provenance) with
        ``source`` marking which path served it."""
        exact = self.entries.get(record_key(n, k, d, backend, dtype))
        if exact is not None:
            return dataclasses.replace(exact, source="cache")
        if not nearest:
            return None
        best, best_dist = None, math.inf
        for rec in self.entries.values():
            if rec.backend != backend or rec.dtype != dtype:
                continue
            dist = (abs(math.log(max(rec.n, 1) / max(n, 1)))
                    + abs(math.log(max(rec.k, 1) / max(k, 1)))
                    + abs(math.log(max(rec.d, 1) / max(d, 1))))
            if dist < best_dist:
                best, best_dist = rec, dist
        if best is None:
            return None
        return dataclasses.replace(best, source="cache-nearest")
