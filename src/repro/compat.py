"""Version compatibility shims for the jax API surface this repo uses.

The repo targets current jax (``jax.shard_map``, ``jax.lax.pcast``) but must
also run on the 0.4.x line where ``shard_map`` still lives in
``jax.experimental`` and varying-manual-axes tracking does not exist yet.
Everything that touches these APIs goes through this module.
"""
from __future__ import annotations

import jax

_PCAST = getattr(jax.lax, "pcast", None)
_PVARY = getattr(jax.lax, "pvary", None)


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``.

    The experimental version gets ``check_rep=False``: its value-based
    replication checker predates loop-carried collective patterns (psum inside
    ``fori_loop``/``while_loop`` bodies) and rejects valid programs that the
    modern varying-manual-axes tracker accepts.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def pvary(x, axes):
    """Mark an array device-varying over `axes`. Tries the pcast and pvary
    spellings (the primitive moved between jax versions); identity before
    varying-manual-axes tracking existed at all."""
    if _PCAST is not None:
        return _PCAST(x, axes, to="varying")
    if _PVARY is not None:
        return _PVARY(x, axes)
    return x


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict (jax<=0.4.x wraps it in a list)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _register_optimization_barrier_batcher() -> None:
    """jax 0.4.x has no vmap rule for ``optimization_barrier`` (added
    upstream later). The rule is trivial — the barrier is identity-shaped,
    so bind the batched operands and pass the batch dims through — and the
    engine's reduction-tree pinning uses the barrier under ``vmap``
    (fit_batched), so register it when missing."""
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching
    except ImportError:                                  # pragma: no cover
        return
    if optimization_barrier_p in batching.primitive_batchers:
        return

    def _rule(args, dims):
        return optimization_barrier_p.bind(*args), dims

    batching.primitive_batchers[optimization_barrier_p] = _rule


_register_optimization_barrier_batcher()
