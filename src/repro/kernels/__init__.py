"""Pallas TPU kernels for the compute hot-spots (validated interpret=True
on CPU; compiled by Mosaic on a TPU backend — ops.py dispatches):

  kmeans_distance.py  — THE PAPER: fused D^2 min-update + partial sums;
                        centroid block VMEM-resident (constant-memory
                        analogue) or streamed (global-memory analogue);
                        cached-norm inputs, bf16 streaming, and bound-gated
                        variants that SKIP provably-unchanged tiles via a
                        scalar-prefetched index map + aliased outputs, plus
                        the one-pass prologue kernel (norms + tile balls)
  lloyd_assign.py     — fused assignment + per-cluster sums/counts
                        (one-hot MXU matmul instead of atomics; cached-norm
                        input, bf16 streaming)
  flash_attention.py  — online-softmax attention, scores never leave VMEM
                        (EXPERIMENTS.md §Perf B memory-term kernel)
  pq_decode.py        — decode attention over k-means++ product-quantized
                        KV codes; codebooks VMEM-resident (§Perf C)

ops.py — jit'd dispatch wrappers;  ref.py — pure-jnp oracles for every
kernel (tests sweep shapes/dtypes and assert_allclose against these).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
