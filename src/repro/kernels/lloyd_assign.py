"""Fused Lloyd assignment kernel: nearest-centroid assignment + per-cluster
partial sums/counts in ONE pass over the points (the clustering-phase hot spot).

Centroids are VMEM-resident (constant-memory analogue); the per-cluster
accumulators (k, d) and (k,) live in VMEM for the whole grid (output blocks
with a constant index_map), initialized at grid step 0 — the TPU version of a
privatized-then-reduced histogram, with the one-hot matmul on the MXU instead
of atomics (TPU has no global atomics; this is the idiomatic replacement).

Like the seeding-round kernels, the assignment kernel streams a cached fp32
``||x||^2`` input (norm caching: computed once per fit, not once per
iteration) and keeps the point/centroid tiles in their input dtype into the
MXU (bf16 streams at half the HBM bytes; accumulators stay fp32). Raw
kernels take ``interpret`` explicitly — ``kernels.ops`` owns the default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the one shared definition of the cached-norm matmul-form D^2 — the
# fused==pallas bitwise-parity claims hang off every kernel using it
from repro.kernels.kmeans_distance import tile_d2 as _tile_d2


def _assign_kernel(n_valid_ref, pts_ref, norms_ref, cents_ref, assign_ref,
                   md_ref, sums_ref, counts_ref, *, block_n: int):
    i = pl.program_id(0)
    x = pts_ref[...].astype(jnp.float32)        # (block_n, d) for accumulation
    xn = norms_ref[...].astype(jnp.float32)
    d2 = _tile_d2(pts_ref[...], cents_ref[...], xn)     # (block_n, k)

    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    m = jnp.where(valid, m, 0.0)

    assign_ref[...] = a
    md_ref[...] = m

    # one-hot matmul instead of atomics: (k, block_n) @ (block_n, d) on the MXU
    k = cents_ref.shape[0]
    onehot = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = tile_sums
        counts_ref[...] = tile_counts

    @pl.when(i > 0)
    def _accum():
        sums_ref[...] += tile_sums
        counts_ref[...] += tile_counts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_pallas(points: jax.Array, norms: jax.Array,
                        centroids: jax.Array, *, block_n: int,
                        interpret: bool):
    """Returns (assignment (n,) int32, min_d2 (n,), sums (k, d), counts (k,)).
    ``norms`` is the cached fp32 ``||x||^2`` (n,)."""
    n, d = points.shape
    k = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    n_valid = jnp.array([n], jnp.int32)

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_assign_kernel, block_n=block_n),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),      # cached ||x||^2
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # resident
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # VMEM accumulator
            pl.BlockSpec((k,), lambda i: (0,)),            # VMEM accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:n], md[:n], sums, counts


# ---------------------------------------------------------------------------
# batch-grid variant (multi-tenant clustering: B independent problems)
# ---------------------------------------------------------------------------


def _assign_kernel_batched(n_valid_ref, pts_ref, norms_ref, cents_ref,
                           assign_ref, md_ref, sums_ref, counts_ref, *,
                           block_n: int):
    """Grid step (b, i): same math as `_assign_kernel` for problem b's tile i.

    The (1, k, d)/(1, k) accumulators map to problem b's slot; the grid
    iterates i fastest, so `i == 0` re-initializes them exactly once per
    problem."""
    i = pl.program_id(1)
    x = pts_ref[0].astype(jnp.float32)          # (block_n, d)
    xn = norms_ref[0].astype(jnp.float32)
    d2 = _tile_d2(pts_ref[0], cents_ref[0], xn)

    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    m = jnp.where(valid, m, 0.0)

    assign_ref[0] = a
    md_ref[0] = m

    k = cents_ref.shape[1]
    onehot = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[0] = tile_sums
        counts_ref[0] = tile_counts

    @pl.when(i > 0)
    def _accum():
        sums_ref[0] += tile_sums
        counts_ref[0] += tile_counts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_batched_pallas(points: jax.Array, norms: jax.Array,
                                centroids: jax.Array, *, block_n: int,
                                interpret: bool):
    """Batched Lloyd half-step over B independent problems in ONE launch.

    points (B, n, d), norms (B, n), centroids (B, k, d) -> (assignment (B, n)
    int32, min_d2 (B, n), sums (B, k, d), counts (B, k)). Row b matches
    `lloyd_assign_pallas` on problem b; the grid gains a leading batch
    dimension and the per-cluster accumulators gain a per-problem slot."""
    B, n, d = points.shape
    k = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    n_valid = jnp.array([n], jnp.int32)

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_assign_kernel_batched, block_n=block_n),
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),   # accumulator
            pl.BlockSpec((1, k), lambda b, i: (b, 0)),         # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, k, d), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:, :n], md[:, :n], sums, counts
