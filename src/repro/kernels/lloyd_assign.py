"""Fused Lloyd assignment kernels: nearest-centroid assignment + per-cluster
partial sums/counts in ONE pass over the points (the clustering-phase hot spot).

Centroids are VMEM-resident (constant-memory analogue); the per-cluster
accumulators (k, d) and (k,) live in VMEM for the whole grid (output blocks
with a constant index_map), initialized at grid step 0 — the TPU version of a
privatized-then-reduced histogram, with the one-hot matmul on the MXU instead
of atomics (TPU has no global atomics; this is the idiomatic replacement).

Like the seeding-round kernels, the assignment kernels stream a cached fp32
``||x||^2`` input (norm caching: computed once per fit, not once per
iteration) and keep the point/centroid tiles in their input dtype into the
MXU (bf16 streams at half the HBM bytes; accumulators stay fp32). Raw
kernels take ``interpret`` explicitly — ``kernels.ops`` owns the default.

Two kernel families:

* ``lloyd_assign_pallas`` (+ batched) — the historical accumulated form: one
  (k, d)/(k,) VMEM accumulator pair for the whole grid. Used by the legacy
  weighted / mini-batch paths.
* ``lloyd_assign_tiled_pallas`` / ``lloyd_assign_gated_pallas`` (+ batched)
  — the BOUNDED-LLOYD form: per-tile inertia partials and second-best gaps,
  per-point labels/D², and HIERARCHICAL per-cluster accumulators: every
  ``tps = tiles_per_super(n_tiles)`` consecutive tiles accumulate into ONE
  per-super-tile (k, d)/(k,) slot (sequential, ascending tile order inside
  the kernel; the engine reduces the small (n_super, k, d) array outside),
  capping accumulator HBM at O(n_super·k·d) instead of the flat
  O(n_tiles·k·d). The gated variant reuses PR 3's scalar-prefetched
  compacted index map + ``input_output_aliases``: a tile whose movement
  bound proves no label can change is neither computed nor fetched, its
  per-tile/per-point output blocks keep the previous iteration's
  (bitwise-identical) values, and the accumulator aliasing happens at the
  SUPER level — a super-tile's slot is carried only when ALL its tiles
  skip, so callers must pass super-aligned active sets
  (``core.bounds.expand_active_supers``; the ops wrapper enforces it).
  Inside an active tile the FINE level fires: per-point Hamerly bounds
  (carried ``point_lb`` + exact ``min_d2``) short-circuit the k-way
  distance recomputation for every point whose label and D² provably
  cannot change (``core.bounds.assign_point_prune``) — the selects are
  value-noops, pinned bitwise, and the ``pruned`` output counts them. The
  reduction tree is shared by the gated and ungated tiled kernels, which
  is what makes bounded-vs-unbounded fits bitwise comparable end to end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the one shared definition of the cached-norm matmul-form D^2 — the
# fused==pallas bitwise-parity claims hang off every kernel using it
from repro.kernels.kmeans_distance import tile_d2 as _tile_d2
# the ONE definition of the fine-level per-point prune test (the pure-JAX
# gate model evaluates the same function — single source of truth)
from repro.core.bounds import assign_point_prune as _assign_point_prune


def _assign_kernel(n_valid_ref, pts_ref, norms_ref, cents_ref, assign_ref,
                   md_ref, sums_ref, counts_ref, *, block_n: int):
    i = pl.program_id(0)
    x = pts_ref[...].astype(jnp.float32)        # (block_n, d) for accumulation
    xn = norms_ref[...].astype(jnp.float32)
    d2 = _tile_d2(pts_ref[...], cents_ref[...], xn)     # (block_n, k)

    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    m = jnp.where(valid, m, 0.0)

    assign_ref[...] = a
    md_ref[...] = m

    # one-hot matmul instead of atomics: (k, block_n) @ (block_n, d) on the MXU
    k = cents_ref.shape[0]
    onehot = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = tile_sums
        counts_ref[...] = tile_counts

    @pl.when(i > 0)
    def _accum():
        sums_ref[...] += tile_sums
        counts_ref[...] += tile_counts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_pallas(points: jax.Array, norms: jax.Array,
                        centroids: jax.Array, *, block_n: int,
                        interpret: bool):
    """Returns (assignment (n,) int32, min_d2 (n,), sums (k, d), counts (k,)).
    ``norms`` is the cached fp32 ``||x||^2`` (n,)."""
    n, d = points.shape
    k = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    n_valid = jnp.array([n], jnp.int32)

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_assign_kernel, block_n=block_n),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),      # cached ||x||^2
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # resident
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # VMEM accumulator
            pl.BlockSpec((k,), lambda i: (0,)),            # VMEM accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:n], md[:n], sums, counts


# ---------------------------------------------------------------------------
# batch-grid variant (multi-tenant clustering: B independent problems)
# ---------------------------------------------------------------------------


def _assign_kernel_batched(n_valid_ref, pts_ref, norms_ref, cents_ref,
                           assign_ref, md_ref, sums_ref, counts_ref, *,
                           block_n: int):
    """Grid step (b, i): same math as `_assign_kernel` for problem b's tile i.

    The (1, k, d)/(1, k) accumulators map to problem b's slot; the grid
    iterates i fastest, so `i == 0` re-initializes them exactly once per
    problem."""
    i = pl.program_id(1)
    x = pts_ref[0].astype(jnp.float32)          # (block_n, d)
    xn = norms_ref[0].astype(jnp.float32)
    d2 = _tile_d2(pts_ref[0], cents_ref[0], xn)

    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    m = jnp.where(valid, m, 0.0)

    assign_ref[0] = a
    md_ref[0] = m

    k = cents_ref.shape[1]
    onehot = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[0] = tile_sums
        counts_ref[0] = tile_counts

    @pl.when(i > 0)
    def _accum():
        sums_ref[0] += tile_sums
        counts_ref[0] += tile_counts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_batched_pallas(points: jax.Array, norms: jax.Array,
                                centroids: jax.Array, *, block_n: int,
                                interpret: bool):
    """Batched Lloyd half-step over B independent problems in ONE launch.

    points (B, n, d), norms (B, n), centroids (B, k, d) -> (assignment (B, n)
    int32, min_d2 (B, n), sums (B, k, d), counts (B, k)). Row b matches
    `lloyd_assign_pallas` on problem b; the grid gains a leading batch
    dimension and the per-cluster accumulators gain a per-problem slot."""
    B, n, d = points.shape
    k = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    n_valid = jnp.array([n], jnp.int32)

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_assign_kernel_batched, block_n=block_n),
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),   # accumulator
            pl.BlockSpec((1, k), lambda b, i: (b, 0)),         # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, k, d), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:, :n], md[:, :n], sums, counts


# ---------------------------------------------------------------------------
# tiled variant (bounded Lloyd): per-tile partial/gap/sums/counts outputs
# ---------------------------------------------------------------------------


def _tile_assign(x_raw, xn, c_raw, valid):
    """Shared per-tile assignment math for the tiled/gated kernels:
    (labels, masked min_d2, tile inertia partial, tile second-best gap,
    per-point second-best lower bound, tile per-cluster sums, tile
    per-cluster counts). The gap/lb are in DISTANCE units (the movement
    bound compares them against centroid movement); a k=1 tile has no
    runner-up, so its gap/lb are +inf."""
    d2 = _tile_d2(x_raw, c_raw, xn)                     # (block_n, k)
    k = d2.shape[1]
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)
    won = a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    second = jnp.min(jnp.where(won, jnp.inf, d2), axis=1)
    gap_pt = jnp.sqrt(second) - jnp.sqrt(m)
    gap = jnp.min(jnp.where(valid, gap_pt, jnp.inf))
    m = jnp.where(valid, m, 0.0)

    x = x_raw.astype(jnp.float32)
    onehot = jnp.where(valid[:, None], won.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)
    return a, m, jnp.sum(m), gap, jnp.sqrt(second), tile_sums, tile_counts


def _tile_assign_pruned(x_raw, xn, c_raw, valid, prev_a, prev_md, prev_lb,
                        delta, thresh, absorb):
    """Fine-level twin of `_tile_assign`: per-point Hamerly pruning inside
    one ACTIVE tile. Points whose own centroid is bitwise unmoved and whose
    carried second-best lower bound clears the movement threshold
    (`core.bounds.assign_point_prune`) short-circuit the k-way distance
    recomputation: label, min_d2 come from the carry (bitwise what a fresh
    compute would produce — the exactness argument in ``core.bounds``), and
    their lb decays by ``absorb`` instead of being re-derived. Returns
    (labels, masked min_d2, tile partial, tile gap, per-point lb,
    pruned-point count, tile sums, tile counts)."""
    prune = _assign_point_prune(prev_a, prev_md, prev_lb, delta, thresh,
                                valid)
    d2 = _tile_d2(x_raw, c_raw, xn)                     # (block_n, k)
    k = d2.shape[1]
    a_f = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m_f = jnp.min(d2, axis=1)
    a = jnp.where(prune, prev_a, a_f)
    m = jnp.where(prune, prev_md, m_f)
    won = a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    second = jnp.min(jnp.where(won, jnp.inf, d2), axis=1)
    # pruned rows carry the decayed bound — their fresh second-best was
    # never (conceptually) computed; fresh rows re-derive it exactly
    lb = jnp.where(prune, prev_lb - absorb, jnp.sqrt(second))
    gap_pt = lb - jnp.sqrt(m)
    gap = jnp.min(jnp.where(valid, gap_pt, jnp.inf))
    m = jnp.where(valid, m, 0.0)

    x = x_raw.astype(jnp.float32)
    onehot = jnp.where(valid[:, None], won.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)
    return (a, m, jnp.sum(m), gap, lb,
            jnp.sum(prune.astype(jnp.float32)), tile_sums, tile_counts)


def _super_accum(cond_first, ssums_ref, scounts_ref, tsums, tcounts, idx):
    """Accumulate one tile's contribution into its super-tile's resident
    accumulator slot at ``ssums_ref[idx]``: re-initialize on the super's
    first visited tile (the freshly-mapped output block is undefined VMEM —
    the where never USES it then), sequential adds after. One shared
    definition keeps the gated and ungated kernels on the same tree."""
    prev_s = jnp.where(cond_first, jnp.zeros_like(tsums), ssums_ref[idx])
    prev_c = jnp.where(cond_first, jnp.zeros_like(tcounts),
                       scounts_ref[idx])
    ssums_ref[idx] = prev_s + tsums
    scounts_ref[idx] = prev_c + tcounts


def _assign_tiled_kernel(n_valid_ref, pts_ref, norms_ref, cents_ref,
                         assign_ref, md_ref, partial_ref, gap_ref, ssums_ref,
                         scounts_ref, *, block_n: int, tps: int):
    i = pl.program_id(0)
    xn = norms_ref[...].astype(jnp.float32)
    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    a, m, part, gap, _, tsums, tcounts = _tile_assign(pts_ref[...], xn,
                                                      cents_ref[...], valid)
    assign_ref[...] = a
    md_ref[...] = m
    partial_ref[0] = part
    gap_ref[0] = gap
    _super_accum(i % tps == 0, ssums_ref, scounts_ref, tsums, tcounts, 0)


@functools.partial(jax.jit, static_argnames=("block_n", "tps", "interpret"))
def lloyd_assign_tiled_pallas(points: jax.Array, norms: jax.Array,
                              centroids: jax.Array, *, block_n: int,
                              tps: int, interpret: bool):
    """Bounded-Lloyd assignment half-step with per-tile scalars and
    HIERARCHICAL per-cluster accumulators.

    Returns (assignment (n,) int32, min_d2 (n,), partials (n_tiles,),
    gaps (n_tiles,), super_sums (n_super, k, d), super_counts (n_super, k))
    where every ``tps`` consecutive tiles share one accumulator slot
    (n_super = ceil(n_tiles / tps)). ``sum(partials)`` is the iteration's
    inertia; ``super_sums.sum(0)`` / ``super_counts.sum(0)`` are the
    centroid-update accumulators — the SAME two-level reduction tree the
    gated kernel produces, so bounded and unbounded fits compare bitwise."""
    n, d = points.shape
    k = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    n_super = -(-grid // tps)
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    n_valid = jnp.array([n], jnp.int32)

    a, md, partials, gaps, ssums, scounts = pl.pallas_call(
        functools.partial(_assign_tiled_kernel, block_n=block_n, tps=tps),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),      # cached ||x||^2
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # resident
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, k, d), lambda i: (i // tps, 0, 0)),  # super
            pl.BlockSpec((1, k), lambda i: (i // tps, 0)),        # super
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((n_super, k, d), jnp.float32),
            jax.ShapeDtypeStruct((n_super, k), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:n], md[:n], partials, gaps, ssums, scounts


def _assign_gated_kernel(ids_ref, meta_ref, pts_ref, norms_ref, cents_ref,
                         delta_ref, thresh_ref, absorb_ref, pa_ref, pmd_ref,
                         plb_ref, pp_ref, pg_ref, pss_ref, psc_ref, pz_ref,
                         assign_ref, md_ref, lb_ref, partial_ref, gap_ref,
                         ssums_ref, scounts_ref, pruned_ref, *, block_n: int,
                         tps: int):
    """Grid step i streams tile ``ids[i]``; steps >= n_active revisit the
    last active tile (VMEM-resident, no HBM fetch) gated off by pl.when.
    ``pa``/``pmd``/``plb`` (the per-point carries) are READ — they feed the
    fine-level per-point prune — and their buffers are donated to the
    matching outputs; the pp/pg/pss/psc/pz refs are never read and live in
    ANY memory space (zero DMA), existing only to carry the aliased buffers
    the skipped tiles'/supers' outputs fall back to. The super-tile
    accumulator re-initializes on each super's first tile (``ids[i] % tps
    == 0`` — the caller guarantees super-aligned active sets, so a super's
    tiles are visited completely and in ascending order)."""
    del pp_ref, pg_ref, pss_ref, psc_ref, pz_ref
    i = pl.program_id(0)

    @pl.when(i < meta_ref[1])
    def _compute():
        t = ids_ref[i]                                 # the REAL tile id
        xn = norms_ref[...].astype(jnp.float32)
        row = t * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
        valid = row < meta_ref[0]
        a, m, part, gap, lb, pruned, tsums, tcounts = _tile_assign_pruned(
            pts_ref[...], xn, cents_ref[...], valid, pa_ref[...],
            pmd_ref[...].astype(jnp.float32),
            plb_ref[...].astype(jnp.float32), delta_ref[...],
            thresh_ref[0], absorb_ref[0])
        assign_ref[...] = a
        md_ref[...] = m
        lb_ref[...] = lb
        partial_ref[0] = part
        gap_ref[0] = gap
        pruned_ref[0] = pruned
        _super_accum(t % tps == 0, ssums_ref, scounts_ref, tsums, tcounts, 0)


@functools.partial(jax.jit, static_argnames=("block_n", "tps", "interpret"))
def lloyd_assign_gated_pallas(points: jax.Array, norms: jax.Array,
                              centroids: jax.Array, delta: jax.Array,
                              thresh: jax.Array, absorb: jax.Array,
                              prev_assign: jax.Array,
                              prev_min_d2: jax.Array, prev_lb: jax.Array,
                              prev_partials: jax.Array, prev_gaps: jax.Array,
                              prev_super_sums: jax.Array,
                              prev_super_counts: jax.Array, ids: jax.Array,
                              meta: jax.Array, *, block_n: int, tps: int,
                              interpret: bool):
    """Bound-gated assignment half-step (two-level exact pruning for Lloyd).

    ``ids``/``meta=[n_valid, n_active]`` come from `core.bounds.compact_ids`
    over a SUPER-ALIGNED active mask (`core.bounds.expand_active_supers` of
    `assign_active_tiles` — the ops wrapper enforces it): only the first
    n_active grid steps fetch + compute; every output block of a skipped
    tile keeps the aliased previous-iteration value, which the movement
    bound proves is bitwise what a recompute would write (labels cannot
    change AND the tile's assigned centroids did not move). The per-cluster
    accumulators are per-SUPER-tile (aliased at super granularity — carried
    iff the whole super skipped). Inside computed tiles the per-point
    Hamerly bound short-circuits stable points (``delta``/``thresh``/
    ``absorb`` from `core.bounds.assign_point_scalars`). Same returns as
    `lloyd_assign_tiled_pallas` plus (lb (n,), pruned (n_tiles,))."""
    n, d = points.shape
    k = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    n_super = -(-grid // tps)
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    pa = jnp.pad(prev_assign.astype(jnp.int32), (0, pad))
    pmd = jnp.pad(prev_min_d2.astype(jnp.float32), (0, pad))
    plb = jnp.pad(prev_lb.astype(jnp.float32), (0, pad))

    # the five pp/pg/pss/psc/pz operands exist ONLY to donate their buffers
    # via input_output_aliases (the kernel never reads them): ANY memory
    # space keeps them in HBM with no per-step VMEM DMA, so active tiles pay
    # zero traffic for those carries and skipped tiles still inherit them
    carry_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    blk = pl.BlockSpec((block_n,), lambda i, ids, meta: (ids[i],))
    one = pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                          # ids, meta
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, ids, meta: (ids[i], 0)),
            blk,                                            # norms
            pl.BlockSpec((k, d), lambda i, ids, meta: (0, 0)),   # resident
            pl.BlockSpec((k,), lambda i, ids, meta: (0,)),  # delta, resident
            one,                                            # thresh
            one,                                            # absorb
            blk,                                            # prev assign
            blk,                                            # prev min_d2
            blk,                                            # prev lb
        ] + [carry_spec] * 5,
        out_specs=[
            blk,                                            # assignment
            blk,                                            # min_d2
            blk,                                            # lb
            one,                                            # partial
            one,                                            # gap
            pl.BlockSpec((1, k, d),
                         lambda i, ids, meta: (ids[i] // tps, 0, 0)),
            pl.BlockSpec((1, k), lambda i, ids, meta: (ids[i] // tps, 0)),
            one,                                            # pruned
        ],
    )
    a, md, lb, partials, gaps, ssums, scounts, pruned = pl.pallas_call(
        functools.partial(_assign_gated_kernel, block_n=block_n, tps=tps),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((n_super, k, d), jnp.float32),
            jax.ShapeDtypeStruct((n_super, k), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        # skipped tiles/supers reuse all of their prior output blocks;
        # skipped tiles report zero pruned points (the donated zeros)
        input_output_aliases={8: 0, 9: 1, 10: 2, 11: 3, 12: 4, 13: 5,
                              14: 6, 15: 7},
        interpret=interpret,
    )(ids, meta, pts, nrm, centroids, delta.astype(jnp.float32),
      thresh.astype(jnp.float32), absorb.astype(jnp.float32), pa, pmd, plb,
      prev_partials.astype(jnp.float32), prev_gaps.astype(jnp.float32),
      prev_super_sums.astype(jnp.float32),
      prev_super_counts.astype(jnp.float32),
      jnp.zeros((grid,), jnp.float32))
    return a[:n], md[:n], lb[:n], partials, gaps, ssums, scounts, pruned


def _assign_tiled_kernel_batched(n_valid_ref, pts_ref, norms_ref, cents_ref,
                                 assign_ref, md_ref, partial_ref, gap_ref,
                                 ssums_ref, scounts_ref, *, block_n: int,
                                 tps: int):
    i = pl.program_id(1)
    xn = norms_ref[0].astype(jnp.float32)
    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    a, m, part, gap, _, tsums, tcounts = _tile_assign(pts_ref[0], xn,
                                                      cents_ref[0], valid)
    assign_ref[0] = a
    md_ref[0] = m
    partial_ref[0, 0] = part
    gap_ref[0, 0] = gap
    _super_accum(i % tps == 0, ssums_ref, scounts_ref, tsums, tcounts,
                 (0, 0))


@functools.partial(jax.jit, static_argnames=("block_n", "tps", "interpret"))
def lloyd_assign_tiled_batched_pallas(points: jax.Array, norms: jax.Array,
                                      centroids: jax.Array, *, block_n: int,
                                      tps: int, interpret: bool):
    """Batch-grid tiled assignment over B independent problems in ONE launch;
    row b is bitwise `lloyd_assign_tiled_pallas` on problem b."""
    B, n, d = points.shape
    k = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    n_super = -(-grid // tps)
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    n_valid = jnp.array([n], jnp.int32)

    a, md, partials, gaps, ssums, scounts = pl.pallas_call(
        functools.partial(_assign_tiled_kernel_batched, block_n=block_n,
                          tps=tps),
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1, k, d), lambda b, i: (b, i // tps, 0, 0)),
            pl.BlockSpec((1, 1, k), lambda b, i: (b, i // tps, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, n_super, k, d), jnp.float32),
            jax.ShapeDtypeStruct((B, n_super, k), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:, :n], md[:, :n], partials, gaps, ssums, scounts


def _assign_gated_kernel_batched(ids_ref, nact_ref, nv_ref, pts_ref,
                                 norms_ref, cents_ref, delta_ref, thresh_ref,
                                 absorb_ref, pa_ref, pmd_ref, plb_ref,
                                 pp_ref, pg_ref, pss_ref, psc_ref, pz_ref,
                                 assign_ref, md_ref, lb_ref, partial_ref,
                                 gap_ref, ssums_ref, scounts_ref, pruned_ref,
                                 *, block_n: int, tps: int):
    """Grid step (b, i) streams tile ids[b, i] of problem b; steps past
    problem b's n_active are no-ops (per-problem compaction)."""
    del pp_ref, pg_ref, pss_ref, psc_ref, pz_ref
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i < nact_ref[b])
    def _compute():
        t = ids_ref[b, i]
        xn = norms_ref[0].astype(jnp.float32)
        row = t * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
        valid = row < nv_ref[0]
        a, m, part, gap, lb, pruned, tsums, tcounts = _tile_assign_pruned(
            pts_ref[0], xn, cents_ref[0], valid, pa_ref[0],
            pmd_ref[0].astype(jnp.float32), plb_ref[0].astype(jnp.float32),
            delta_ref[0], thresh_ref[0, 0], absorb_ref[0, 0])
        assign_ref[0] = a
        md_ref[0] = m
        lb_ref[0] = lb
        partial_ref[0, 0] = part
        gap_ref[0, 0] = gap
        pruned_ref[0, 0] = pruned
        _super_accum(t % tps == 0, ssums_ref, scounts_ref, tsums, tcounts,
                     (0, 0))


@functools.partial(jax.jit, static_argnames=("block_n", "tps", "interpret"))
def lloyd_assign_gated_batched_pallas(
        points: jax.Array, norms: jax.Array, centroids: jax.Array,
        delta: jax.Array, thresh: jax.Array, absorb: jax.Array,
        prev_assign: jax.Array, prev_min_d2: jax.Array, prev_lb: jax.Array,
        prev_partials: jax.Array, prev_gaps: jax.Array,
        prev_super_sums: jax.Array, prev_super_counts: jax.Array,
        ids: jax.Array, n_active: jax.Array, *, block_n: int, tps: int,
        interpret: bool):
    """Batch-grid bound-gated assignment: per-problem compacted
    (super-aligned) active-tile maps ids (B, n_tiles) / n_active (B,).
    Row b is bitwise `lloyd_assign_gated_pallas` on problem b."""
    B, n, d = points.shape
    k = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    n_super = -(-grid // tps)
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    pa = jnp.pad(prev_assign.astype(jnp.int32), ((0, 0), (0, pad)))
    pmd = jnp.pad(prev_min_d2.astype(jnp.float32), ((0, 0), (0, pad)))
    plb = jnp.pad(prev_lb.astype(jnp.float32), ((0, 0), (0, pad)))
    nv = jnp.array([n], jnp.int32)

    # never-read aliased carries: ANY memory space, no per-step DMA
    carry_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    blk = pl.BlockSpec((1, block_n),
                       lambda b, i, ids, na, nv: (b, ids[b, i]))
    one = pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i]))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                      # ids, n_active, n_valid
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1, block_n, d),
                         lambda b, i, ids, na, nv: (b, ids[b, i], 0)),
            blk,                                        # norms
            pl.BlockSpec((1, k, d), lambda b, i, ids, na, nv: (b, 0, 0)),
            pl.BlockSpec((1, k), lambda b, i, ids, na, nv: (b, 0)),  # delta
            one,                                        # thresh
            one,                                        # absorb
            blk,                                        # prev assign
            blk,                                        # prev min_d2
            blk,                                        # prev lb
        ] + [carry_spec] * 5,
        out_specs=[
            blk,                                        # assignment
            blk,                                        # min_d2
            blk,                                        # lb
            one,                                        # partial
            one,                                        # gap
            pl.BlockSpec((1, 1, k, d),
                         lambda b, i, ids, na, nv: (b, ids[b, i] // tps,
                                                    0, 0)),
            pl.BlockSpec((1, 1, k),
                         lambda b, i, ids, na, nv: (b, ids[b, i] // tps, 0)),
            one,                                        # pruned
        ],
    )
    a, md, lb, partials, gaps, ssums, scounts, pruned = pl.pallas_call(
        functools.partial(_assign_gated_kernel_batched, block_n=block_n,
                          tps=tps),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, n_super, k, d), jnp.float32),
            jax.ShapeDtypeStruct((B, n_super, k), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
        ],
        input_output_aliases={9: 0, 10: 1, 11: 2, 12: 3, 13: 4, 14: 5,
                              15: 6, 16: 7},
        interpret=interpret,
    )(ids.astype(jnp.int32), n_active.astype(jnp.int32), nv, pts, nrm,
      centroids, delta.astype(jnp.float32), thresh.astype(jnp.float32),
      absorb.astype(jnp.float32), pa, pmd, plb,
      prev_partials.astype(jnp.float32), prev_gaps.astype(jnp.float32),
      prev_super_sums.astype(jnp.float32),
      prev_super_counts.astype(jnp.float32),
      jnp.zeros((B, grid), jnp.float32))
    return (a[:, :n], md[:, :n], lb[:, :n], partials, gaps, ssums, scounts,
            pruned)
