"""Fused Lloyd assignment kernels: nearest-centroid assignment + per-cluster
partial sums/counts in ONE pass over the points (the clustering-phase hot spot).

Centroids are VMEM-resident (constant-memory analogue); the per-cluster
accumulators (k, d) and (k,) live in VMEM for the whole grid (output blocks
with a constant index_map), initialized at grid step 0 — the TPU version of a
privatized-then-reduced histogram, with the one-hot matmul on the MXU instead
of atomics (TPU has no global atomics; this is the idiomatic replacement).

Like the seeding-round kernels, the assignment kernels stream a cached fp32
``||x||^2`` input (norm caching: computed once per fit, not once per
iteration) and keep the point/centroid tiles in their input dtype into the
MXU (bf16 streams at half the HBM bytes; accumulators stay fp32). Raw
kernels take ``interpret`` explicitly — ``kernels.ops`` owns the default.

Two kernel families:

* ``lloyd_assign_pallas`` (+ batched) — the historical accumulated form: one
  (k, d)/(k,) VMEM accumulator pair for the whole grid. Used by the legacy
  weighted / mini-batch paths.
* ``lloyd_assign_tiled_pallas`` / ``lloyd_assign_gated_pallas`` (+ batched)
  — the BOUNDED-LLOYD form: per-tile outputs (inertia partial, second-best
  gap, per-cluster sums/counts per tile, reduced over the tile axis outside
  the kernel) so the gated variant can reuse PR 3's scalar-prefetched
  compacted index map + ``input_output_aliases``: a tile whose movement
  bound proves no label can change is neither computed nor fetched, and all
  six of its output blocks keep the previous iteration's (bitwise-identical)
  values. The per-tile reduction tree is shared by the gated and ungated
  tiled kernels, which is what makes bounded-vs-unbounded fits bitwise
  comparable end to end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the one shared definition of the cached-norm matmul-form D^2 — the
# fused==pallas bitwise-parity claims hang off every kernel using it
from repro.kernels.kmeans_distance import tile_d2 as _tile_d2


def _assign_kernel(n_valid_ref, pts_ref, norms_ref, cents_ref, assign_ref,
                   md_ref, sums_ref, counts_ref, *, block_n: int):
    i = pl.program_id(0)
    x = pts_ref[...].astype(jnp.float32)        # (block_n, d) for accumulation
    xn = norms_ref[...].astype(jnp.float32)
    d2 = _tile_d2(pts_ref[...], cents_ref[...], xn)     # (block_n, k)

    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    m = jnp.where(valid, m, 0.0)

    assign_ref[...] = a
    md_ref[...] = m

    # one-hot matmul instead of atomics: (k, block_n) @ (block_n, d) on the MXU
    k = cents_ref.shape[0]
    onehot = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = tile_sums
        counts_ref[...] = tile_counts

    @pl.when(i > 0)
    def _accum():
        sums_ref[...] += tile_sums
        counts_ref[...] += tile_counts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_pallas(points: jax.Array, norms: jax.Array,
                        centroids: jax.Array, *, block_n: int,
                        interpret: bool):
    """Returns (assignment (n,) int32, min_d2 (n,), sums (k, d), counts (k,)).
    ``norms`` is the cached fp32 ``||x||^2`` (n,)."""
    n, d = points.shape
    k = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    n_valid = jnp.array([n], jnp.int32)

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_assign_kernel, block_n=block_n),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),      # cached ||x||^2
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # resident
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # VMEM accumulator
            pl.BlockSpec((k,), lambda i: (0,)),            # VMEM accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:n], md[:n], sums, counts


# ---------------------------------------------------------------------------
# batch-grid variant (multi-tenant clustering: B independent problems)
# ---------------------------------------------------------------------------


def _assign_kernel_batched(n_valid_ref, pts_ref, norms_ref, cents_ref,
                           assign_ref, md_ref, sums_ref, counts_ref, *,
                           block_n: int):
    """Grid step (b, i): same math as `_assign_kernel` for problem b's tile i.

    The (1, k, d)/(1, k) accumulators map to problem b's slot; the grid
    iterates i fastest, so `i == 0` re-initializes them exactly once per
    problem."""
    i = pl.program_id(1)
    x = pts_ref[0].astype(jnp.float32)          # (block_n, d)
    xn = norms_ref[0].astype(jnp.float32)
    d2 = _tile_d2(pts_ref[0], cents_ref[0], xn)

    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    m = jnp.where(valid, m, 0.0)

    assign_ref[0] = a
    md_ref[0] = m

    k = cents_ref.shape[1]
    onehot = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[0] = tile_sums
        counts_ref[0] = tile_counts

    @pl.when(i > 0)
    def _accum():
        sums_ref[0] += tile_sums
        counts_ref[0] += tile_counts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_batched_pallas(points: jax.Array, norms: jax.Array,
                                centroids: jax.Array, *, block_n: int,
                                interpret: bool):
    """Batched Lloyd half-step over B independent problems in ONE launch.

    points (B, n, d), norms (B, n), centroids (B, k, d) -> (assignment (B, n)
    int32, min_d2 (B, n), sums (B, k, d), counts (B, k)). Row b matches
    `lloyd_assign_pallas` on problem b; the grid gains a leading batch
    dimension and the per-cluster accumulators gain a per-problem slot."""
    B, n, d = points.shape
    k = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    n_valid = jnp.array([n], jnp.int32)

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_assign_kernel_batched, block_n=block_n),
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),   # accumulator
            pl.BlockSpec((1, k), lambda b, i: (b, 0)),         # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, k, d), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:, :n], md[:, :n], sums, counts


# ---------------------------------------------------------------------------
# tiled variant (bounded Lloyd): per-tile partial/gap/sums/counts outputs
# ---------------------------------------------------------------------------


def _tile_assign(x_raw, xn, c_raw, valid):
    """Shared per-tile assignment math for the tiled/gated kernels:
    (labels, masked min_d2, tile inertia partial, tile second-best gap,
    tile per-cluster sums, tile per-cluster counts). The second-best gap is
    in DISTANCE units (the movement bound compares it against centroid
    movement); a k=1 tile has no runner-up, so its gap is +inf."""
    d2 = _tile_d2(x_raw, c_raw, xn)                     # (block_n, k)
    k = d2.shape[1]
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)
    won = a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    second = jnp.min(jnp.where(won, jnp.inf, d2), axis=1)
    gap_pt = jnp.sqrt(second) - jnp.sqrt(m)
    gap = jnp.min(jnp.where(valid, gap_pt, jnp.inf))
    m = jnp.where(valid, m, 0.0)

    x = x_raw.astype(jnp.float32)
    onehot = jnp.where(valid[:, None], won.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)
    return a, m, jnp.sum(m), gap, tile_sums, tile_counts


def _assign_tiled_kernel(n_valid_ref, pts_ref, norms_ref, cents_ref,
                         assign_ref, md_ref, partial_ref, gap_ref, tsums_ref,
                         tcounts_ref, *, block_n: int):
    i = pl.program_id(0)
    xn = norms_ref[...].astype(jnp.float32)
    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    a, m, part, gap, tsums, tcounts = _tile_assign(pts_ref[...], xn,
                                                   cents_ref[...], valid)
    assign_ref[...] = a
    md_ref[...] = m
    partial_ref[0] = part
    gap_ref[0] = gap
    tsums_ref[0] = tsums
    tcounts_ref[0] = tcounts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_tiled_pallas(points: jax.Array, norms: jax.Array,
                              centroids: jax.Array, *, block_n: int,
                              interpret: bool):
    """Bounded-Lloyd assignment half-step with PER-TILE outputs.

    Returns (assignment (n,) int32, min_d2 (n,), partials (n_tiles,),
    gaps (n_tiles,), tile_sums (n_tiles, k, d), tile_counts (n_tiles, k)).
    ``sum(partials)`` is the iteration's inertia; ``tile_sums.sum(0)`` /
    ``tile_counts.sum(0)`` are the centroid-update accumulators — the SAME
    reduction tree the gated kernel produces, so bounded and unbounded fits
    compare bitwise."""
    n, d = points.shape
    k = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    n_valid = jnp.array([n], jnp.int32)

    a, md, partials, gaps, tsums, tcounts = pl.pallas_call(
        functools.partial(_assign_tiled_kernel, block_n=block_n),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),      # cached ||x||^2
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # resident
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid, k, d), jnp.float32),
            jax.ShapeDtypeStruct((grid, k), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:n], md[:n], partials, gaps, tsums, tcounts


def _assign_gated_kernel(ids_ref, meta_ref, pts_ref, norms_ref, cents_ref,
                         pa_ref, pmd_ref, pp_ref, pg_ref, pts_s_ref,
                         ptc_ref, assign_ref, md_ref, partial_ref, gap_ref,
                         tsums_ref, tcounts_ref, *, block_n: int):
    """Grid step i streams tile ``ids[i]``; steps >= n_active revisit the
    last active tile (VMEM-resident, no HBM fetch) gated off by pl.when.
    The prev_* refs are never read — they carry the aliased buffers the
    skipped tiles' six outputs fall back to, and live in ANY memory space
    so active tiles pay no DMA for them."""
    del pa_ref, pmd_ref, pp_ref, pg_ref, pts_s_ref, ptc_ref
    i = pl.program_id(0)

    @pl.when(i < meta_ref[1])
    def _compute():
        t = ids_ref[i]                                 # the REAL tile id
        xn = norms_ref[...].astype(jnp.float32)
        row = t * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
        valid = row < meta_ref[0]
        a, m, part, gap, tsums, tcounts = _tile_assign(pts_ref[...], xn,
                                                       cents_ref[...], valid)
        assign_ref[...] = a
        md_ref[...] = m
        partial_ref[0] = part
        gap_ref[0] = gap
        tsums_ref[0] = tsums
        tcounts_ref[0] = tcounts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_gated_pallas(points: jax.Array, norms: jax.Array,
                              centroids: jax.Array, prev_assign: jax.Array,
                              prev_min_d2: jax.Array,
                              prev_partials: jax.Array, prev_gaps: jax.Array,
                              prev_tile_sums: jax.Array,
                              prev_tile_counts: jax.Array, ids: jax.Array,
                              meta: jax.Array, *, block_n: int,
                              interpret: bool):
    """Bound-gated assignment half-step (exact tile skipping for Lloyd).

    ``ids``/``meta=[n_valid, n_active]`` come from `core.bounds.compact_ids`
    over `core.bounds.assign_active_tiles`: only the first n_active grid
    steps fetch + compute; every output block of a skipped tile keeps the
    aliased previous-iteration value, which the movement bound proves is
    bitwise what a recompute would write (labels cannot change AND the
    tile's assigned centroids did not move). Same returns as
    `lloyd_assign_tiled_pallas`."""
    n, d = points.shape
    k = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    pa = jnp.pad(prev_assign.astype(jnp.int32), (0, pad))
    pmd = jnp.pad(prev_min_d2.astype(jnp.float32), (0, pad))

    # the six prev_* operands exist ONLY to donate their buffers via
    # input_output_aliases (the kernel never reads them): ANY memory space
    # keeps them in HBM with no per-step VMEM DMA, so active tiles pay zero
    # traffic for the carries and skipped tiles still inherit them
    carry_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                          # ids, meta
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, ids, meta: (ids[i], 0)),
            pl.BlockSpec((block_n,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((k, d), lambda i, ids, meta: (0, 0)),   # resident
        ] + [carry_spec] * 6,
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((block_n,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((1, k, d), lambda i, ids, meta: (ids[i], 0, 0)),
            pl.BlockSpec((1, k), lambda i, ids, meta: (ids[i], 0)),
        ],
    )
    a, md, partials, gaps, tsums, tcounts = pl.pallas_call(
        functools.partial(_assign_gated_kernel, block_n=block_n),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid, k, d), jnp.float32),
            jax.ShapeDtypeStruct((grid, k), jnp.float32),
        ],
        # skipped tiles reuse all six of their prior output blocks
        input_output_aliases={5: 0, 6: 1, 7: 2, 8: 3, 9: 4, 10: 5},
        interpret=interpret,
    )(ids, meta, pts, nrm, centroids, pa, pmd,
      prev_partials.astype(jnp.float32), prev_gaps.astype(jnp.float32),
      prev_tile_sums.astype(jnp.float32),
      prev_tile_counts.astype(jnp.float32))
    return a[:n], md[:n], partials, gaps, tsums, tcounts


def _assign_tiled_kernel_batched(n_valid_ref, pts_ref, norms_ref, cents_ref,
                                 assign_ref, md_ref, partial_ref, gap_ref,
                                 tsums_ref, tcounts_ref, *, block_n: int):
    i = pl.program_id(1)
    xn = norms_ref[0].astype(jnp.float32)
    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    a, m, part, gap, tsums, tcounts = _tile_assign(pts_ref[0], xn,
                                                   cents_ref[0], valid)
    assign_ref[0] = a
    md_ref[0] = m
    partial_ref[0, 0] = part
    gap_ref[0, 0] = gap
    tsums_ref[0, 0] = tsums
    tcounts_ref[0, 0] = tcounts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_tiled_batched_pallas(points: jax.Array, norms: jax.Array,
                                      centroids: jax.Array, *, block_n: int,
                                      interpret: bool):
    """Batch-grid tiled assignment over B independent problems in ONE launch;
    row b is bitwise `lloyd_assign_tiled_pallas` on problem b."""
    B, n, d = points.shape
    k = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    n_valid = jnp.array([n], jnp.int32)

    a, md, partials, gaps, tsums, tcounts = pl.pallas_call(
        functools.partial(_assign_tiled_kernel_batched, block_n=block_n),
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1, k, d), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, k), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, grid, k, d), jnp.float32),
            jax.ShapeDtypeStruct((B, grid, k), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids)
    return a[:, :n], md[:, :n], partials, gaps, tsums, tcounts


def _assign_gated_kernel_batched(ids_ref, nact_ref, nv_ref, pts_ref,
                                 norms_ref, cents_ref, pa_ref, pmd_ref,
                                 pp_ref, pg_ref, pts_s_ref, ptc_ref,
                                 assign_ref, md_ref, partial_ref, gap_ref,
                                 tsums_ref, tcounts_ref, *, block_n: int):
    """Grid step (b, i) streams tile ids[b, i] of problem b; steps past
    problem b's n_active are no-ops (per-problem compaction)."""
    del pa_ref, pmd_ref, pp_ref, pg_ref, pts_s_ref, ptc_ref
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i < nact_ref[b])
    def _compute():
        t = ids_ref[b, i]
        xn = norms_ref[0].astype(jnp.float32)
        row = t * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
        valid = row < nv_ref[0]
        a, m, part, gap, tsums, tcounts = _tile_assign(pts_ref[0], xn,
                                                       cents_ref[0], valid)
        assign_ref[0] = a
        md_ref[0] = m
        partial_ref[0, 0] = part
        gap_ref[0, 0] = gap
        tsums_ref[0, 0] = tsums
        tcounts_ref[0, 0] = tcounts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_gated_batched_pallas(
        points: jax.Array, norms: jax.Array, centroids: jax.Array,
        prev_assign: jax.Array, prev_min_d2: jax.Array,
        prev_partials: jax.Array, prev_gaps: jax.Array,
        prev_tile_sums: jax.Array, prev_tile_counts: jax.Array,
        ids: jax.Array, n_active: jax.Array, *, block_n: int,
        interpret: bool):
    """Batch-grid bound-gated assignment: per-problem compacted active-tile
    maps ids (B, n_tiles) / n_active (B,). Row b is bitwise
    `lloyd_assign_gated_pallas` on problem b."""
    B, n, d = points.shape
    k = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    pa = jnp.pad(prev_assign.astype(jnp.int32), ((0, 0), (0, pad)))
    pmd = jnp.pad(prev_min_d2.astype(jnp.float32), ((0, 0), (0, pad)))
    nv = jnp.array([n], jnp.int32)

    # never-read aliased carries: ANY memory space, no per-step DMA
    carry_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                      # ids, n_active, n_valid
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1, block_n, d),
                         lambda b, i, ids, na, nv: (b, ids[b, i], 0)),
            pl.BlockSpec((1, block_n),
                         lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, k, d), lambda b, i, ids, na, nv: (b, 0, 0)),
        ] + [carry_spec] * 6,
        out_specs=[
            pl.BlockSpec((1, block_n),
                         lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, block_n),
                         lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1, k, d),
                         lambda b, i, ids, na, nv: (b, ids[b, i], 0, 0)),
            pl.BlockSpec((1, 1, k),
                         lambda b, i, ids, na, nv: (b, ids[b, i], 0)),
        ],
    )
    a, md, partials, gaps, tsums, tcounts = pl.pallas_call(
        functools.partial(_assign_gated_kernel_batched, block_n=block_n),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, grid, k, d), jnp.float32),
            jax.ShapeDtypeStruct((B, grid, k), jnp.float32),
        ],
        input_output_aliases={6: 0, 7: 1, 8: 2, 9: 3, 10: 4, 11: 5},
        interpret=interpret,
    )(ids.astype(jnp.int32), n_active.astype(jnp.int32), nv, pts, nrm,
      centroids, pa, pmd, prev_partials.astype(jnp.float32),
      prev_gaps.astype(jnp.float32), prev_tile_sums.astype(jnp.float32),
      prev_tile_counts.astype(jnp.float32))
    return a[:, :n], md[:, :n], partials, gaps, tsums, tcounts
