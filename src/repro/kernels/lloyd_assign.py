"""Fused Lloyd assignment kernel: nearest-centroid assignment + per-cluster
partial sums/counts in ONE pass over the points (the clustering-phase hot spot).

Centroids are VMEM-resident (constant-memory analogue); the per-cluster
accumulators (k, d) and (k,) live in VMEM for the whole grid (output blocks
with a constant index_map), initialized at grid step 0 — the TPU version of a
privatized-then-reduced histogram, with the one-hot matmul on the MXU instead
of atomics (TPU has no global atomics; this is the idiomatic replacement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(n_valid_ref, pts_ref, cents_ref, assign_ref, md_ref,
                   sums_ref, counts_ref, *, block_n: int):
    i = pl.program_id(0)
    x = pts_ref[...].astype(jnp.float32)        # (block_n, d)
    c = cents_ref[...].astype(jnp.float32)      # (k, d) resident

    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xn - 2.0 * dots + cn[None, :], 0.0)   # (block_n, k)

    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    m = jnp.where(valid, m, 0.0)

    assign_ref[...] = a
    md_ref[...] = m

    # one-hot matmul instead of atomics: (k, block_n) @ (block_n, d) on the MXU
    k = c.shape[0]
    onehot = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = tile_sums
        counts_ref[...] = tile_counts

    @pl.when(i > 0)
    def _accum():
        sums_ref[...] += tile_sums
        counts_ref[...] += tile_counts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_pallas(points: jax.Array, centroids: jax.Array, *,
                        block_n: int = 1024, interpret: bool = True):
    """Returns (assignment (n,) int32, min_d2 (n,), sums (k, d), counts (k,))."""
    n, d = points.shape
    k = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    n_valid = jnp.array([n], jnp.int32)

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_assign_kernel, block_n=block_n),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # resident
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # VMEM accumulator
            pl.BlockSpec((k,), lambda i: (0,)),            # VMEM accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, centroids)
    return a[:n], md[:n], sums, counts


# ---------------------------------------------------------------------------
# batch-grid variant (multi-tenant clustering: B independent problems)
# ---------------------------------------------------------------------------


def _assign_kernel_batched(n_valid_ref, pts_ref, cents_ref, assign_ref,
                           md_ref, sums_ref, counts_ref, *, block_n: int):
    """Grid step (b, i): same math as `_assign_kernel` for problem b's tile i.

    The (1, k, d)/(1, k) accumulators map to problem b's slot; the grid
    iterates i fastest, so `i == 0` re-initializes them exactly once per
    problem."""
    i = pl.program_id(1)
    x = pts_ref[0].astype(jnp.float32)          # (block_n, d)
    c = cents_ref[0].astype(jnp.float32)        # (k, d)

    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xn - 2.0 * dots + cn[None, :], 0.0)

    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    m = jnp.where(valid, m, 0.0)

    assign_ref[0] = a
    md_ref[0] = m

    k = c.shape[0]
    onehot = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    tile_sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    tile_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[0] = tile_sums
        counts_ref[0] = tile_counts

    @pl.when(i > 0)
    def _accum():
        sums_ref[0] += tile_sums
        counts_ref[0] += tile_counts


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_assign_batched_pallas(points: jax.Array, centroids: jax.Array, *,
                                block_n: int = 1024, interpret: bool = True):
    """Batched Lloyd half-step over B independent problems in ONE launch.

    points (B, n, d), centroids (B, k, d) -> (assignment (B, n) int32,
    min_d2 (B, n), sums (B, k, d), counts (B, k)). Row b matches
    `lloyd_assign_pallas` on problem b; the grid gains a leading batch
    dimension and the per-cluster accumulators gain a per-problem slot."""
    B, n, d = points.shape
    k = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    n_valid = jnp.array([n], jnp.int32)

    a, md, sums, counts = pl.pallas_call(
        functools.partial(_assign_kernel_batched, block_n=block_n),
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),   # accumulator
            pl.BlockSpec((1, k), lambda b, i: (b, 0)),         # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.int32),
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, k, d), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, centroids)
    return a[:, :n], md[:, :n], sums, counts
