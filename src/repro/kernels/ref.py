"""Pure-jnp oracles for the Pallas kernels. Every kernel test sweeps shapes and
dtypes and asserts allclose against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _d2(x: jax.Array, c: jax.Array) -> jax.Array:
    """(n, d) x (k, d) -> (n, k) squared distances in fp32."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    xn = jnp.sum(x * x, axis=-1, keepdims=True)
    cn = jnp.sum(c * c, axis=-1)
    return jnp.maximum(xn - 2.0 * (x @ c.T) + cn[None, :], 0.0)


def distance_min_update_ref(points: jax.Array, centroids: jax.Array,
                            min_d2: jax.Array):
    """Oracle for kernels.kmeans_distance: one k-means++ seeding round.

    Returns (new_min_d2 (n,), total (,)): the min-distance array updated against
    the new centroid(s) and the sum of the updated array (the paper's
    thrust::reduce term).
    """
    d2 = jnp.min(_d2(points, centroids), axis=1)
    new = jnp.minimum(min_d2.astype(jnp.float32), d2)
    return new, jnp.sum(new)


def row_min_d2_ref(points: jax.Array, idx: jax.Array, centroids: jax.Array,
                   count: jax.Array) -> jax.Array:
    """Oracle for kernels.row_min_d2: D^2 of the single row ``idx`` to its
    nearest among the first ``count`` rows of ``centroids`` (slots >= count
    are masked to +inf, so count == 0 returns +inf — the rejection sampler's
    empty-pending case, where min(q, +inf) == q keeps the accept ratio
    bitwise at 1). Scalar fp32; O(count * d) reads."""
    x = points[idx].astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d2 = jnp.sum((x[None, :] - c) ** 2, axis=1)
    slot = jnp.arange(c.shape[0])
    return jnp.min(jnp.where(slot < count, d2, jnp.inf))


def tile_cap_ref(centers: jax.Array, radii: jax.Array, pending: jax.Array,
                 count: jax.Array) -> jax.Array:
    """Oracle for kernels.tile_cap: per-tile rejection-envelope cap from tile
    summaries only. For tile t with ball (center_t, r_t) every row satisfies
    ``d(x_i, c) <= d(center_t, c) + r_t`` (triangle inequality), so

        cap_t = (min_{j < count} d(center_t, pending_j) + r_t)^2

    dominates every row's CURRENT min_d2 against the pending block — a valid
    per-tile upper bound the rejection sampler may shrink its stale envelope
    with (Raff-style, applied to sampling). Slots >= count are masked to
    +inf, so count == 0 returns +inf everywhere (no tightening). (n_tiles,)
    fp32; O(n_tiles * count * d) — tile summaries, never rows."""
    d2 = _d2(centers, pending)
    slot = jnp.arange(pending.shape[0])
    dc2 = jnp.min(jnp.where(slot[None, :] < count, d2, jnp.inf), axis=1)
    cap = (jnp.sqrt(dc2) + radii.astype(jnp.float32)) ** 2
    return jnp.where(count > 0, cap, jnp.inf)


def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0,
                        q_offset=0):
    """Oracle for kernels.flash_attention: exact softmax attention in fp32.
    q (B, Sq, H, hd); k/v (B, Skv, KH, hd) with H = KH * G."""
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    qf = q.astype(jnp.float32).reshape(B, Sq, KH, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kf) * (hd ** -0.5)
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, vf)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def lloyd_assign_ref(points: jax.Array, centroids: jax.Array):
    """Oracle for kernels.lloyd_assign: fused assignment + per-cluster partials.

    Returns (assignment (n,) int32, min_d2 (n,), sums (k, d) fp32, counts (k,)).
    """
    d2 = _d2(points, centroids)
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(a, k, dtype=jnp.float32)
    sums = onehot.T @ points.astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    return a, m, sums, counts


def lloyd_assign_tiled_ref(points: jax.Array, centroids: jax.Array,
                           block_n: int, tps: int = 1):
    """Oracle for kernels.lloyd_assign_tiled: per-tile assignment outputs
    with hierarchical (super-tile) accumulators.

    Returns (assignment (n,) int32, min_d2 (n,), partials (n_tiles,),
    gaps (n_tiles,), super_sums (n_super, k, d), super_counts (n_super, k))
    where every ``tps`` consecutive tiles share one accumulator slot.
    ``gaps`` is the per-tile min of the second-best margin in distance units
    (+inf for k == 1 — no runner-up exists)."""
    n, d = points.shape
    k = centroids.shape[0]
    d2 = _d2(points, centroids)
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)
    won = jax.nn.one_hot(a, k, dtype=bool)
    second = jnp.min(jnp.where(won, jnp.inf, d2), axis=1)
    gap_pt = jnp.sqrt(second) - jnp.sqrt(m)

    pad = (-n) % block_n
    n_tiles = (n + pad) // block_n
    valid = jnp.arange(n + pad) < n
    mt = jnp.pad(m, (0, pad)).reshape(n_tiles, block_n)
    partials = jnp.sum(mt, axis=1)
    gaps = jnp.min(jnp.pad(gap_pt, (0, pad), constant_values=jnp.inf)
                   .reshape(n_tiles, block_n), axis=1)
    onehot = jnp.where(valid[:, None],
                       jnp.pad(won.astype(jnp.float32), ((0, pad), (0, 0))),
                       0.0).reshape(n_tiles, block_n, k)
    xt = jnp.pad(points.astype(jnp.float32),
                 ((0, pad), (0, 0))).reshape(n_tiles, block_n, d)
    tile_sums = jnp.einsum("tbk,tbd->tkd", onehot, xt)
    tile_counts = jnp.sum(onehot, axis=1)
    spad = (-n_tiles) % tps
    super_sums = jnp.pad(tile_sums, ((0, spad), (0, 0), (0, 0))) \
        .reshape(-1, tps, k, d).sum(axis=1)
    super_counts = jnp.pad(tile_counts, ((0, spad), (0, 0))) \
        .reshape(-1, tps, k).sum(axis=1)
    return a, m, partials, gaps, super_sums, super_counts
