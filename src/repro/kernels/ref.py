"""Pure-jnp oracles for the Pallas kernels. Every kernel test sweeps shapes and
dtypes and asserts allclose against these.

The IVF twins (`ivf_scan_ref`, `ivf_adc_scan_ref`) are BITWISE mirrors, not
merely allclose oracles: they replay the exact op sequence of the Pallas scan
kernels — ``lax.map`` over queries (NOT vmap, so no batched 3-D contraction
changes the arithmetic), the same shared gate predicate and lexicographic
merge, ``jnp.where``-selected carries standing in for ``pl.when``. One
deliberate deviation: scores are computed for the WHOLE padded array in one
dot per query and sliced per tile from the materialized result, instead of
dotting each (block_n, d) tile inside the loop. Per-row dot results are
invariant to the operand's row count (the fused==pallas precedent), so the
full-array rows equal the kernel's tile-dot rows bitwise — whereas a dot fed
by a ``dynamic_slice`` inside the same jit gets the slice fused into it with
a DIFFERENT accumulation order (observed 1-ulp drift on CPU), which would
break the mirror. `ivf_bruteforce_topk` is the independent ground truth the
exactness tests pin both against at ``nprobe == nlist``."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bounds import ivf_gate_skip
from repro.core.topk import IDX_SENTINEL, init_topk, lex_topk, merge_topk


def _d2(x: jax.Array, c: jax.Array) -> jax.Array:
    """(n, d) x (k, d) -> (n, k) squared distances in fp32."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    xn = jnp.sum(x * x, axis=-1, keepdims=True)
    cn = jnp.sum(c * c, axis=-1)
    return jnp.maximum(xn - 2.0 * (x @ c.T) + cn[None, :], 0.0)


def distance_min_update_ref(points: jax.Array, centroids: jax.Array,
                            min_d2: jax.Array):
    """Oracle for kernels.kmeans_distance: one k-means++ seeding round.

    Returns (new_min_d2 (n,), total (,)): the min-distance array updated against
    the new centroid(s) and the sum of the updated array (the paper's
    thrust::reduce term).
    """
    d2 = jnp.min(_d2(points, centroids), axis=1)
    new = jnp.minimum(min_d2.astype(jnp.float32), d2)
    return new, jnp.sum(new)


def row_min_d2_ref(points: jax.Array, idx: jax.Array, centroids: jax.Array,
                   count: jax.Array) -> jax.Array:
    """Oracle for kernels.row_min_d2: D^2 of the single row ``idx`` to its
    nearest among the first ``count`` rows of ``centroids`` (slots >= count
    are masked to +inf, so count == 0 returns +inf — the rejection sampler's
    empty-pending case, where min(q, +inf) == q keeps the accept ratio
    bitwise at 1). Scalar fp32; O(count * d) reads."""
    x = points[idx].astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d2 = jnp.sum((x[None, :] - c) ** 2, axis=1)
    slot = jnp.arange(c.shape[0])
    return jnp.min(jnp.where(slot < count, d2, jnp.inf))


def tile_cap_ref(centers: jax.Array, radii: jax.Array, pending: jax.Array,
                 count: jax.Array) -> jax.Array:
    """Oracle for kernels.tile_cap: per-tile rejection-envelope cap from tile
    summaries only. For tile t with ball (center_t, r_t) every row satisfies
    ``d(x_i, c) <= d(center_t, c) + r_t`` (triangle inequality), so

        cap_t = (min_{j < count} d(center_t, pending_j) + r_t)^2

    dominates every row's CURRENT min_d2 against the pending block — a valid
    per-tile upper bound the rejection sampler may shrink its stale envelope
    with (Raff-style, applied to sampling). Slots >= count are masked to
    +inf, so count == 0 returns +inf everywhere (no tightening). (n_tiles,)
    fp32; O(n_tiles * count * d) — tile summaries, never rows."""
    d2 = _d2(centers, pending)
    slot = jnp.arange(pending.shape[0])
    dc2 = jnp.min(jnp.where(slot[None, :] < count, d2, jnp.inf), axis=1)
    cap = (jnp.sqrt(dc2) + radii.astype(jnp.float32)) ** 2
    return jnp.where(count > 0, cap, jnp.inf)


def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0,
                        q_offset=0):
    """Oracle for kernels.flash_attention: exact softmax attention in fp32.
    q (B, Sq, H, hd); k/v (B, Skv, KH, hd) with H = KH * G."""
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    qf = q.astype(jnp.float32).reshape(B, Sq, KH, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kf) * (hd ** -0.5)
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, vf)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def lloyd_assign_ref(points: jax.Array, centroids: jax.Array):
    """Oracle for kernels.lloyd_assign: fused assignment + per-cluster partials.

    Returns (assignment (n,) int32, min_d2 (n,), sums (k, d) fp32, counts (k,)).
    """
    d2 = _d2(points, centroids)
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(a, k, dtype=jnp.float32)
    sums = onehot.T @ points.astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    return a, m, sums, counts


def lloyd_assign_tiled_ref(points: jax.Array, centroids: jax.Array,
                           block_n: int, tps: int = 1):
    """Oracle for kernels.lloyd_assign_tiled: per-tile assignment outputs
    with hierarchical (super-tile) accumulators.

    Returns (assignment (n,) int32, min_d2 (n,), partials (n_tiles,),
    gaps (n_tiles,), super_sums (n_super, k, d), super_counts (n_super, k))
    where every ``tps`` consecutive tiles share one accumulator slot.
    ``gaps`` is the per-tile min of the second-best margin in distance units
    (+inf for k == 1 — no runner-up exists)."""
    n, d = points.shape
    k = centroids.shape[0]
    d2 = _d2(points, centroids)
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    m = jnp.min(d2, axis=1)
    won = jax.nn.one_hot(a, k, dtype=bool)
    second = jnp.min(jnp.where(won, jnp.inf, d2), axis=1)
    gap_pt = jnp.sqrt(second) - jnp.sqrt(m)

    pad = (-n) % block_n
    n_tiles = (n + pad) // block_n
    valid = jnp.arange(n + pad) < n
    mt = jnp.pad(m, (0, pad)).reshape(n_tiles, block_n)
    partials = jnp.sum(mt, axis=1)
    gaps = jnp.min(jnp.pad(gap_pt, (0, pad), constant_values=jnp.inf)
                   .reshape(n_tiles, block_n), axis=1)
    onehot = jnp.where(valid[:, None],
                       jnp.pad(won.astype(jnp.float32), ((0, pad), (0, 0))),
                       0.0).reshape(n_tiles, block_n, k)
    xt = jnp.pad(points.astype(jnp.float32),
                 ((0, pad), (0, 0))).reshape(n_tiles, block_n, d)
    tile_sums = jnp.einsum("tbk,tbd->tkd", onehot, xt)
    tile_counts = jnp.sum(onehot, axis=1)
    spad = (-n_tiles) % tps
    super_sums = jnp.pad(tile_sums, ((0, spad), (0, 0), (0, 0))) \
        .reshape(-1, tps, k, d).sum(axis=1)
    super_counts = jnp.pad(tile_counts, ((0, spad), (0, 0))) \
        .reshape(-1, tps, k).sum(axis=1)
    return a, m, partials, gaps, super_sums, super_counts


@functools.partial(jax.jit, static_argnames=("k",))
def ivf_bruteforce_topk(queries: jax.Array, points: jax.Array,
                        norms: jax.Array, *, k: int):
    """Ground-truth batched top-k: every query against EVERY row, one
    lexicographic sort. Shares the scan kernels' arithmetic — cached
    ``||x||^2``, a (n, d) x (1, d) fp32 dot per query (per-row results are
    invariant to row-block height, the fused==pallas precedent), the same
    ``max(xn - 2 dots + qn, 0)`` op order, and `core.topk`'s (value, index)
    tie-break — so the gated scan at ``nprobe == nlist`` must match it
    BITWISE, which is exactly what the exactness tests assert.

    Returns (dists (Q, k) fp32, rows (Q, k) int32)."""
    n = points.shape[0]
    nrm = norms.astype(jnp.float32)
    rows = jnp.arange(n, dtype=jnp.int32)

    def one(q_row):
        q = q_row[None, :].astype(jnp.float32)
        qn = jnp.sum(q * q)
        dots = jax.lax.dot_general(points, q.astype(points.dtype),
                                   (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)[:, 0]
        d2 = jnp.maximum(nrm - 2.0 * dots + qn, 0.0)
        return lex_topk(d2, rows, k)

    return jax.lax.map(one, queries)


@functools.partial(jax.jit,
                   static_argnames=("k", "block_n", "gate"))
def ivf_scan_ref(queries: jax.Array, points: jax.Array, norms: jax.Array,
                 centers: jax.Array, radii: jax.Array, ids: jax.Array,
                 n_active: jax.Array, *, k: int, block_n: int, gate: bool):
    """Bitwise twin of kernels.ivf_scan.ivf_scan_pallas: the gated
    cluster-local exact scan replayed in pure jnp — ``lax.map`` over queries,
    ``fori_loop`` over the compacted tile stream, ``jnp.where``-selected
    carries mirroring ``pl.when``. Same signature and returns."""
    n, d = points.shape
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    ctr = centers.astype(jnp.float32)
    rad = radii.astype(jnp.float32)
    iota = jnp.arange(block_n, dtype=jnp.int32)

    def one(args):
        q_row, tile_ids, nact = args
        q = q_row[None, :].astype(jnp.float32)
        qn = jnp.sum(q * q)
        # whole-array scores once, sliced per tile below (see module note:
        # bitwise equal to the kernel's per-tile dots, unlike a sliced-
        # operand dot inside the loop)
        dots = jax.lax.dot_general(
            pts, q.astype(pts.dtype), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        d2_all = jnp.maximum(nrm - 2.0 * dots + qn, 0.0)

        def step(i, carry):
            tv, ti, ns = carry
            t = tile_ids[i]
            visit = i < nact
            if gate:
                c = jax.lax.dynamic_slice(ctr, (t, 0), (1, d))
                diff = c - q
                dc = jnp.sqrt(jnp.sum(diff * diff))
                cn = jnp.sqrt(jnp.sum(c * c))
                skip = ivf_gate_skip(dc, rad[t], cn, qn, tv[k - 1])
            else:
                skip = jnp.full((), False)
            ns = ns + jnp.where(visit, skip.astype(jnp.int32), 0)
            d2 = jax.lax.dynamic_slice(d2_all, (t * block_n,), (block_n,))
            row = t * block_n + iota
            valid = row < n
            cv = jnp.where(valid, d2, jnp.inf)
            ci = jnp.where(valid, row, IDX_SENTINEL)
            nv_, ni_ = merge_topk(tv, ti, cv, ci, k)
            take = visit & jnp.logical_not(skip)
            return (jnp.where(take, nv_, tv), jnp.where(take, ni_, ti), ns)

        tv0, ti0 = init_topk(k)
        return jax.lax.fori_loop(0, grid, step,
                                 (tv0, ti0, jnp.zeros((), jnp.int32)))

    return jax.lax.map(one, (queries, ids.astype(jnp.int32),
                             n_active.astype(jnp.int32)))


@functools.partial(jax.jit,
                   static_argnames=("k", "block_n", "gate"))
def ivf_adc_scan_ref(queries: jax.Array, lut: jax.Array, qdots: jax.Array,
                     codes: jax.Array, labels: jax.Array, u: jax.Array,
                     centers: jax.Array, radii: jax.Array, ids: jax.Array,
                     n_active: jax.Array, *, k: int, block_n: int,
                     gate: bool):
    """Bitwise twin of kernels.ivf_scan.ivf_adc_scan_pallas: the PQ/ADC
    gated scan — per-query LUT contraction against one-hot codes, routing
    dots gathered through one-hot labels — replayed in pure jnp. Same
    signature and returns."""
    n, n_sub = codes.shape
    n_codes = lut.shape[2]
    nlist = qdots.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    cds = jnp.pad(codes, ((0, pad), (0, 0)))
    lab = jnp.pad(labels.astype(jnp.int32), (0, pad))
    up = jnp.pad(u.astype(jnp.float32), (0, pad))
    d = queries.shape[1]
    ctr = centers.astype(jnp.float32)
    rad = radii.astype(jnp.float32)
    iota = jnp.arange(block_n, dtype=jnp.int32)
    code_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_codes), 2)
    list_iota = jax.lax.broadcasted_iota(jnp.int32, (1, nlist), 1)

    def one(args):
        q_row, q_lut, q_dot, tile_ids, nact = args
        q = q_row[None, :].astype(jnp.float32)
        qn = jnp.sum(q * q)
        flat_lut = q_lut.astype(jnp.float32).reshape(n_sub * n_codes)
        qd = q_dot.astype(jnp.float32)
        # whole-array ADC scores once, sliced per tile below (see module
        # note on bitwise row-count invariance of the one-hot dots)
        n_pad = n + pad
        onehot = (cds[:, :, None].astype(jnp.int32)
                  == code_iota).astype(jnp.float32)
        qr = jax.lax.dot_general(
            onehot.reshape(n_pad, n_sub * n_codes), flat_lut,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        onl = (lab[:, None] == list_iota).astype(jnp.float32)
        qc = jax.lax.dot_general(onl, qd, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        d2_all = jnp.maximum(qn - 2.0 * (qr + qc) + up, 0.0)

        def step(i, carry):
            tv, ti, ns = carry
            t = tile_ids[i]
            visit = i < nact
            if gate:
                c = jax.lax.dynamic_slice(ctr, (t, 0), (1, d))
                diff = c - q
                dc = jnp.sqrt(jnp.sum(diff * diff))
                cn = jnp.sqrt(jnp.sum(c * c))
                skip = ivf_gate_skip(dc, rad[t], cn, qn, tv[k - 1])
            else:
                skip = jnp.full((), False)
            ns = ns + jnp.where(visit, skip.astype(jnp.int32), 0)
            d2 = jax.lax.dynamic_slice(d2_all, (t * block_n,), (block_n,))
            row = t * block_n + iota
            valid = row < n
            cv = jnp.where(valid, d2, jnp.inf)
            ci = jnp.where(valid, row, IDX_SENTINEL)
            nv_, ni_ = merge_topk(tv, ti, cv, ci, k)
            take = visit & jnp.logical_not(skip)
            return (jnp.where(take, nv_, tv), jnp.where(take, ni_, ti), ns)

        tv0, ti0 = init_topk(k)
        return jax.lax.fori_loop(0, grid, step,
                                 (tv0, ti0, jnp.zeros((), jnp.int32)))

    return jax.lax.map(one, (queries, lut, qdots, ids.astype(jnp.int32),
                             n_active.astype(jnp.int32)))
