"""Flash attention (Pallas TPU): online-softmax attention with the score
matrix NEVER materialized to HBM.

Why it's here: the dry-run roofline shows every dense train/prefill cell is
MEMORY-bound, dominated by attention-score traffic — at the HLO level the
blocked-softmax scan still writes O(B*H*Sq*Skv) f32 score/prob blocks to HBM
each layer. This kernel keeps the (block_q, block_k) score tile, the running
max/sum and the output accumulator in VMEM across the sequential TPU grid,
so HBM traffic drops to O(q + k + v + out) — the §Perf iteration for the
memory term (EXPERIMENTS.md §Perf B).

Layout: grid (B, H, nq, nk) — the kv dim iterates innermost (TPU grids are
sequential), with VMEM scratch carrying (m, l, acc) across kv steps for one
(b, h, iq) tile. GQA: the kv BlockSpec maps query head h -> kv head h // G.
Causal + sliding-window masking by global position; fully-masked tiles skip
the matmuls under pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, seq_q: int, seq_kv: int,
            causal: bool, window: int, cap: float, scale: float,
            q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = (q_offset + iq * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0))
    k_pos = (ik * block_k
             + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1))
    mask = (k_pos < seq_kv) & (q_pos < seq_q + q_offset)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)

    # skip tiles that are entirely masked (causal upper triangle / window)
    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap > 0:
            s = cap * jnp.tanh(s / cap)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                            # (block_q,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "cap", "block_q", "block_k",
                              "q_offset", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, cap: float = 0.0,
                    block_q: int = 512, block_k: int = 512,
                    q_offset: int = 0,
                    interpret: bool | None = None) -> jax.Array:
    """q (B, Sq, H, hd); k/v (B, Skv, KH, hd), H = KH * G. Returns like q.

    interpret=None defers to `kernels.ops.default_interpret` (compiled on
    TPU, interpreted elsewhere) — the single place that default lives.

    VMEM working set per grid step: q/k/v/out tiles + the (block_q, hd) f32
    accumulator — block 512, hd 128: ~1.8 MB, far under the ~64 MB budget,
    leaving the Pallas pipeline room to double-buffer the k/v streams.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = max(H // KH, 1)
    scale = hd ** -0.5

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    qt = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    nq = qt.shape[2] // block_q
    nk = kt.shape[2] // block_k

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, seq_q=Sq, seq_kv=Skv,
        causal=causal, window=window, cap=cap, scale=scale, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


def hbm_bytes_model(B: int, Sq: int, Skv: int, H: int, KH: int, hd: int,
                    dtype_bytes: int = 2) -> dict:
    """Analytic HBM traffic: this kernel vs the HLO blocked-softmax path.
    Used by the §Perf memory-term iteration (the kernel cannot lower on the
    CPU dry-run backend, so its effect on the roofline is derived)."""
    kernel = dtype_bytes * (B * Sq * H * hd            # q read
                            + 2 * B * Skv * KH * hd    # k, v read (per q-pass:
                            + B * Sq * H * hd)         # out write    see note)
    # the kv stream re-reads k/v once per q block row that touches it; for
    # causal attention that is ~nq/2 passes — report the worst case nq passes
    hlo_scores = 4 * B * H * Sq * Skv                  # f32 score + prob blocks
    return {"kernel_bytes": kernel, "hlo_score_bytes_lower_bound": hlo_scores}
