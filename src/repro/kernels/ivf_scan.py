"""Gated cluster-local IVF scan kernels (batched top-k query serving).

A trained k-means model IS an inverted-file index: ``serve.ivf`` routes each
query to its top-``nprobe`` centroids and scans only those clusters' tiles.
The two kernels here are the scan: grid ``(Q, n_tiles)`` where the inner
dimension streams a PER-QUERY compacted probed-tile id list through the
scalar-prefetch channel — the same trick as ``kmeans_distance``'s gated
round kernel (`core.bounds.compact_ids`), so tiles outside the probed lists
are neither fetched nor computed; trailing steps revisit the last probed
tile (already VMEM-resident) and are compute-gated off by ``pl.when``.

Two scoring paths share the scan skeleton:

* **exact** (`ivf_scan_pallas`) — matmul-form fp32 D^2 against the raw rows
  (cached ``||x||^2`` streamed like every round kernel);
* **PQ/ADC** (`ivf_adc_scan_pallas`) — distances to the PQ-RECONSTRUCTED
  rows ``x̂ = c_list + decode(code)``, assembled without ever
  materializing ``x̂``: ``‖q − x̂‖² = ‖q‖² − 2(q·c_list + q·r̂) + ‖x̂‖²``
  where ``q·r̂`` is a per-query inner-product LUT contracted against the
  uint8 codes via the one-hot-matmul MXU pattern of ``pq_decode``, and
  ``q·c_list`` reuses the routing dots through a one-hot over the streamed
  row labels. ``‖x̂‖²`` is a per-row build-time constant.

Layered on top, the per-tile triangle-inequality gate
(`core.bounds.ivf_gate_skip`): a probed tile whose ball provably cannot
beat the carried kth-best distance is skipped as a bitwise value-noop (the
ADC path gates against balls computed over the RECONSTRUCTED rows, so its
scores — true distances to x̂ — satisfy the same triangle bound). The fp32
blocked top-k ``(d2, row)``-lexicographic merge (`core.topk.merge_topk`)
is carried across tiles in VMEM scratch, making the scan bitwise equal to
a global brute-force top-k at ``nprobe == nlist``.

Raw kernels take ``interpret`` EXPLICITLY — ``kernels.ops`` chooses the
on-TPU/off-TPU default, as everywhere else.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the ONE definition of the kth-distance ball gate and of the lexicographic
# blocked merge — the pure-jnp twins in kernels.ref evaluate the same
# functions, so kernel and model share a single source of truth
from repro.core.bounds import ivf_gate_skip as _gate_skip
from repro.core.topk import IDX_SENTINEL, merge_topk


def _tile_ball(q, ctr_ref, rad_ref):
    """(dc, radius, ||center||, ||q||^2) for the gate, from the streamed
    (1, d) ball-center block + (1,) radius block."""
    ctr = ctr_ref[...].astype(jnp.float32)
    diff = ctr - q
    dc = jnp.sqrt(jnp.sum(diff * diff))
    cn = jnp.sqrt(jnp.sum(ctr * ctr))
    return dc, rad_ref[0], cn


def _merge_block(tv_scr, ti_scr, d2, row, n_valid, *, k):
    """Mask padded rows to the (+inf, INT32_MAX) sentinel and fold the block
    into the carried top-k scratch."""
    valid = row < n_valid
    cv = jnp.where(valid, d2, jnp.inf)
    ci = jnp.where(valid, row, IDX_SENTINEL)
    nv, ni = merge_topk(tv_scr[...], ti_scr[...], cv, ci, k)
    tv_scr[...] = nv
    ti_scr[...] = ni


def _ivf_scan_kernel(ids_ref, nact_ref, nv_ref, q_ref, pts_ref, xn_ref,
                     ctr_ref, rad_ref, dist_ref, idx_ref, skip_ref,
                     tv_scr, ti_scr, ns_scr, *, block_n: int, k: int,
                     gate: bool):
    """Grid step (qi, i) scores probed tile ``ids[qi, i]`` for query qi;
    steps past ``n_active[qi]`` are no-ops. Top-k scratch carries across the
    sequential inner dimension; outputs are written at the final step."""
    qi = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        tv_scr[...] = jnp.full_like(tv_scr, jnp.inf)
        ti_scr[...] = jnp.full_like(ti_scr, IDX_SENTINEL)
        ns_scr[0] = 0

    @pl.when(i < nact_ref[qi])
    def _visit():
        t = ids_ref[qi, i]
        q = q_ref[...].astype(jnp.float32)              # (1, d)
        qn = jnp.sum(q * q)
        if gate:
            dc, r, cn = _tile_ball(q, ctr_ref, rad_ref)
            skip = _gate_skip(dc, r, cn, qn, tv_scr[k - 1])
        else:
            skip = jnp.full((), False)
        ns_scr[0] += skip.astype(jnp.int32)

        @pl.when(jnp.logical_not(skip))
        def _score():
            xn = xn_ref[...].astype(jnp.float32)        # (block_n,)
            dots = jax.lax.dot_general(
                pts_ref[...], q.astype(pts_ref.dtype),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)[:, 0]
            d2 = jnp.maximum(xn - 2.0 * dots + qn, 0.0)
            row = t * block_n + jax.lax.broadcasted_iota(
                jnp.int32, (block_n,), 0)
            _merge_block(tv_scr, ti_scr, d2, row, nv_ref[0], k=k)

    @pl.when(i == pl.num_programs(1) - 1)
    def _finalize():
        dist_ref[0, :] = tv_scr[...]
        idx_ref[0, :] = ti_scr[...]
        skip_ref[0] = ns_scr[0]


@functools.partial(jax.jit,
                   static_argnames=("k", "block_n", "gate", "interpret"))
def ivf_scan_pallas(queries: jax.Array, points: jax.Array, norms: jax.Array,
                    centers: jax.Array, radii: jax.Array, ids: jax.Array,
                    n_active: jax.Array, *, k: int, block_n: int, gate: bool,
                    interpret: bool):
    """Exact gated cluster-local scan.

    queries (Q, d); points (n, d) label-sorted rows; norms (n,) cached fp32
    ``||x||^2``; centers/radii the tile ball summaries; ids (Q, n_tiles) /
    n_active (Q,) the per-query compacted probed-tile maps
    (`core.bounds.compact_ids` over the probed-list coverage). Returns
    ``(dists (Q, k) fp32, rows (Q, k) int32, gate_skipped (Q,) int32)`` —
    rows index the SORTED layout (the caller maps through its permutation);
    unfilled slots hold the (+inf, INT32_MAX) sentinel."""
    Q, d = queries.shape
    n = points.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    nv = jnp.array([n], jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                   # ids, n_active, n_valid
        grid=(Q, grid),
        in_specs=[
            pl.BlockSpec((1, d), lambda qi, i, ids, na, nv: (qi, 0)),
            pl.BlockSpec((block_n, d),
                         lambda qi, i, ids, na, nv: (ids[qi, i], 0)),
            pl.BlockSpec((block_n,),
                         lambda qi, i, ids, na, nv: (ids[qi, i],)),
            pl.BlockSpec((1, d), lambda qi, i, ids, na, nv: (ids[qi, i], 0)),
            pl.BlockSpec((1,), lambda qi, i, ids, na, nv: (ids[qi, i],)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda qi, i, ids, na, nv: (qi, 0)),
            pl.BlockSpec((1, k), lambda qi, i, ids, na, nv: (qi, 0)),
            pl.BlockSpec((1,), lambda qi, i, ids, na, nv: (qi,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
            pltpu.VMEM((1,), jnp.int32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ivf_scan_kernel, block_n=block_n, k=k, gate=gate),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
            jax.ShapeDtypeStruct((Q,), jnp.int32),
        ],
        interpret=interpret,
    )(ids.astype(jnp.int32), n_active.astype(jnp.int32), nv,
      queries, pts, nrm, centers.astype(jnp.float32),
      radii.astype(jnp.float32))


def _ivf_adc_kernel(ids_ref, nact_ref, nv_ref, q_ref, lut_ref, qdot_ref,
                    codes_ref, lab_ref, u_ref, ctr_ref, rad_ref,
                    dist_ref, idx_ref, skip_ref, tv_scr, ti_scr, ns_scr, *,
                    block_n: int, k: int, gate: bool):
    """ADC twin of `_ivf_scan_kernel`: scores are exact distances to the
    PQ-reconstructed rows, assembled from the per-query LUT + routing dots +
    per-row ``||x̂||^2`` — codes stream at n_sub bytes/row instead of the
    raw 4d. The gate compares against balls over the RECONSTRUCTED rows, so
    it is a value-noop for ADC scores too."""
    qi = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        tv_scr[...] = jnp.full_like(tv_scr, jnp.inf)
        ti_scr[...] = jnp.full_like(ti_scr, IDX_SENTINEL)
        ns_scr[0] = 0

    @pl.when(i < nact_ref[qi])
    def _visit():
        t = ids_ref[qi, i]
        q = q_ref[...].astype(jnp.float32)              # (1, d)
        qn = jnp.sum(q * q)
        if gate:
            dc, r, cn = _tile_ball(q, ctr_ref, rad_ref)
            skip = _gate_skip(dc, r, cn, qn, tv_scr[k - 1])
        else:
            skip = jnp.full((), False)
        ns_scr[0] += skip.astype(jnp.int32)

        @pl.when(jnp.logical_not(skip))
        def _score():
            codes = codes_ref[...]                      # (block_n, n_sub) u8
            n_sub = codes.shape[1]
            n_codes = lut_ref.shape[2]
            nlist = qdot_ref.shape[1]
            # q·r̂ per row: one-hot(codes) contracted against the LUT — the
            # pq_decode one-hot-matmul lookup, flattened to a single MXU dot
            onehot = (codes[:, :, None].astype(jnp.int32)
                      == jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_codes),
                                                  2)).astype(jnp.float32)
            qr = jax.lax.dot_general(
                onehot.reshape(block_n, n_sub * n_codes),
                lut_ref[0].reshape(n_sub * n_codes),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)     # (block_n,)
            # q·c_list per row: one-hot over the streamed labels against the
            # per-query routing dots (same MXU-gather idiom)
            onl = (lab_ref[...][:, None]
                   == jax.lax.broadcasted_iota(jnp.int32, (1, nlist), 1)
                   ).astype(jnp.float32)
            qc = jax.lax.dot_general(onl, qdot_ref[0],
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            d2 = jnp.maximum(qn - 2.0 * (qr + qc)
                             + u_ref[...].astype(jnp.float32), 0.0)
            row = t * block_n + jax.lax.broadcasted_iota(
                jnp.int32, (block_n,), 0)
            _merge_block(tv_scr, ti_scr, d2, row, nv_ref[0], k=k)

    @pl.when(i == pl.num_programs(1) - 1)
    def _finalize():
        dist_ref[0, :] = tv_scr[...]
        idx_ref[0, :] = ti_scr[...]
        skip_ref[0] = ns_scr[0]


@functools.partial(jax.jit,
                   static_argnames=("k", "block_n", "gate", "interpret"))
def ivf_adc_scan_pallas(queries: jax.Array, lut: jax.Array, qdots: jax.Array,
                        codes: jax.Array, labels: jax.Array, u: jax.Array,
                        centers: jax.Array, radii: jax.Array, ids: jax.Array,
                        n_active: jax.Array, *, k: int, block_n: int,
                        gate: bool, interpret: bool):
    """PQ/ADC gated cluster-local scan.

    queries (Q, d); lut (Q, n_sub, n_codes) per-query inner-product LUT
    ``lut[s, c] = q_s · codebook[s, c]`` over the RESIDUAL codebook; qdots
    (Q, nlist) routing dots ``q · centroid_l``; codes (n, n_sub) uint8;
    labels (n,) int32 per-row list ids; u (n,) fp32 ``||x̂||^2``;
    centers/radii the tile balls over the reconstructed rows. Returns the
    `ivf_scan_pallas` triple with ADC distances."""
    Q, d = queries.shape
    n, n_sub = codes.shape
    n_codes = lut.shape[2]
    nlist = qdots.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    cds = jnp.pad(codes, ((0, pad), (0, 0)))
    lab = jnp.pad(labels.astype(jnp.int32), (0, pad))
    up = jnp.pad(u.astype(jnp.float32), (0, pad))
    nv = jnp.array([n], jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                   # ids, n_active, n_valid
        grid=(Q, grid),
        in_specs=[
            pl.BlockSpec((1, d), lambda qi, i, ids, na, nv: (qi, 0)),
            pl.BlockSpec((1, n_sub, n_codes),
                         lambda qi, i, ids, na, nv: (qi, 0, 0)),
            pl.BlockSpec((1, nlist), lambda qi, i, ids, na, nv: (qi, 0)),
            pl.BlockSpec((block_n, n_sub),
                         lambda qi, i, ids, na, nv: (ids[qi, i], 0)),
            pl.BlockSpec((block_n,),
                         lambda qi, i, ids, na, nv: (ids[qi, i],)),
            pl.BlockSpec((block_n,),
                         lambda qi, i, ids, na, nv: (ids[qi, i],)),
            pl.BlockSpec((1, d), lambda qi, i, ids, na, nv: (ids[qi, i], 0)),
            pl.BlockSpec((1,), lambda qi, i, ids, na, nv: (ids[qi, i],)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda qi, i, ids, na, nv: (qi, 0)),
            pl.BlockSpec((1, k), lambda qi, i, ids, na, nv: (qi, 0)),
            pl.BlockSpec((1,), lambda qi, i, ids, na, nv: (qi,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
            pltpu.VMEM((1,), jnp.int32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ivf_adc_kernel, block_n=block_n, k=k, gate=gate),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
            jax.ShapeDtypeStruct((Q,), jnp.int32),
        ],
        interpret=interpret,
    )(ids.astype(jnp.int32), n_active.astype(jnp.int32), nv,
      queries, lut.astype(jnp.float32), qdots.astype(jnp.float32), cds, lab,
      up, centers.astype(jnp.float32), radii.astype(jnp.float32))
