"""jit'd public wrappers around the Pallas kernels.

On a TPU backend the kernels compile to Mosaic; everywhere else they run in
interpret mode (Python evaluation of the kernel body — bit-correct, slow),
which is how this CPU container validates them. THIS module is the single
place that default is chosen (`default_interpret`): the raw kernels in
``kmeans_distance`` / ``lloyd_assign`` require ``interpret`` explicitly, so
bypassing these wrappers can never silently run interpreted on real TPU.

Block sizes are chosen so the working set (points tile + resident centroids
+ cached-norms block + accumulators + per-tile partials + bound-state
blocks) fits a v5e VMEM budget of ~64 MB with double buffering.

The wrappers carry a `custom_vmap` rule: `jax.vmap` over them dispatches to
the batch-grid kernel variants (one launch with a leading batch grid
dimension) instead of relying on the generic pallas batching rule — this is
what lets the engine's `seed_batched`/`fit_batched` vmap hit real batched
kernels with the VMEM budget accounted for. The bound-gated wrapper does the
same for the gated batch-grid kernel (per-problem compacted tile maps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.kernels.kmeans_distance import (
    distance_min_update_batched_pallas, distance_min_update_gated_pallas,
    distance_min_update_gated_batched_pallas, distance_min_update_pallas,
    row_min_d2_pallas, seed_prologue_pallas, tile_cap_pallas)
from repro.core.bounds import point_norms  # noqa: F401  (re-exported: the
#   cached-norm input the kernels stream; wrappers compute it on the fly
#   when the caller has no prologue cache)
from repro.core.guards import KernelFailureError
from repro.kernels.lloyd_assign import (lloyd_assign_batched_pallas,
                                        lloyd_assign_gated_batched_pallas,
                                        lloyd_assign_gated_pallas,
                                        lloyd_assign_pallas,
                                        lloyd_assign_tiled_batched_pallas,
                                        lloyd_assign_tiled_pallas)

_VMEM_BUDGET = 48 * 1024 * 1024  # leave headroom out of ~64-128MB

# The graceful-degradation order when a Pallas kernel fails to compile or
# launch: each pallas-flavoured local backend degrades to the fused XLA
# backend (same math, no Mosaic), which itself degrades to the looped
# reference. `None` terminates the chain — an exhausted chain re-raises the
# KernelFailureError to the caller. ClusterEngine._dispatch walks this map;
# the mesh backend substitutes its LOCAL backend through the same chain.
FALLBACK_CHAIN: dict = {
    "pallas": "fused",
    "pallas_constant": "fused",
    "pallas_fused": "fused",
    "fused": "reference",
    "global": "reference",
    "reference": None,
    "serial": None,
}

# Fault-injection hook (see repro.testing.faults.force_kernel_failure): when
# set to a reason string, EVERY public kernel wrapper raises
# KernelFailureError at trace time — on this CPU container the kernels run
# in interpret mode, so a forced trace-time raise is exactly where a real
# Mosaic compile/launch failure would surface from under jit.
_FORCED_FAILURE: str | None = None


def _check_forced() -> None:
    if _FORCED_FAILURE is not None:
        raise KernelFailureError(
            f"pallas kernel launch failed (forced: {_FORCED_FAILURE})")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """THE kernel-execution default: compiled on TPU, interpreted elsewhere.
    Every entry point whose ``interpret`` is None resolves it here."""
    return not _on_tpu()


def pick_block_n(d: int, k: int, *, dtype_bytes: int = 4,
                 max_block: int = 4096, batched: bool = False) -> int:
    """Largest power-of-two point-tile height whose double-buffered working set
    fits the VMEM budget. Accounted per grid step:

      2 x (bn, d) point tile           (double-buffered HBM->VMEM stream;
                                        dtype_bytes=2 budgets the half-width
                                        bf16 streaming blocks)
      2 x (bn,) fp32 cached-norms block (double-buffered alongside the points)
      (k, d) resident centroid block
      (bn, k) distance tile + ~4 per-point vectors
      fp32 accumulators: (k, d) sums + (k,) counts + the per-tile partial
        (the seeding kernel's thrust::reduce analogue)
      bound-state blocks: previous-partial/tile-max in + partial/tile-max out
        scalars per step, double-buffered (the gated kernel's skip state)
      per-point bound blocks: the fine-level gates stream the prologue's
        fp32 ``center_d`` block (seeding, 2 buffers) and the assignment
        carries' int32 label + fp32 min_d2 + fp32 point_lb aliased in/out
        block pairs, plus the (k,) movement vector and the per-tile
        dc/margin/thresh/absorb scalars
      hierarchical accumulator: the tiled/gated Lloyd kernels keep ONE
        per-SUPER-tile (k, d)+(k,) cluster sums/counts block resident (plus
        the gated kernel's aliased prev block in flight) — per-tile sums no
        longer stream through VMEM per step

    `batched=True` budgets the batch-grid kernels, whose centroid block is
    re-fetched per problem and therefore double-buffered like the point
    stream (one extra (k, d) operand block in flight)."""
    bn = max_block
    while bn > 128:
        working = sum(vmem_working_set(d, k, bn, dtype_bytes=dtype_bytes,
                                       batched=batched).values())
        if working <= _VMEM_BUDGET:
            return bn
        bn //= 2
    return 128


def vmem_working_set(d: int, k: int, bn: int, *, dtype_bytes: int = 4,
                     batched: bool = False) -> dict[str, int]:
    """THE itemized per-grid-step VMEM accounting `pick_block_n` budgets —
    one shared table so tests (and the autotuner's candidate filter) assert
    against the implementation instead of hand-copied constants. Keys name
    the resident buffers; the budget is ``sum(values()) <= _VMEM_BUDGET``."""
    ws = {
        # double-buffered point tile + resident centroids + (bn, k) distance
        # tile + ~4 per-point vectors, all at the stream dtype
        "stream": dtype_bytes * (2 * bn * d + k * d + bn * k + 4 * bn),
        "norms": 4 * 2 * bn,                # cached ||x||^2 (fp32, 2 buffers)
        "accumulators": 4 * (k * d + k + 8),   # fp32 sums/counts + partial
        "bound_scalars": 4 * 2 * 4,            # bound-state scalar blocks
        "super_accumulators": 4 * 2 * (k * d + k),  # super sums/counts out
                                            #   block (+ gated aliased prev)
        "point_carries": 4 * 6 * bn,        # assignment/min_d2/point_lb
                                            #   aliased i/o block pairs
        "center_d": 4 * 2 * bn,             # center_d block (fp32, 2 bufs)
        "movement": 4 * k,                  # movement vector (k,)
        "gate_scalars": 4 * 2 * 8,          # dc/margin/thresh/absorb +
                                            #   gap/partial/pruned scalars
    }
    if batched:
        ws["batched_centroids"] = dtype_bytes * k * d  # second centroid buf
    return ws


def choose_block_n(n: int, d: int, k: int, *, batched: bool = False) -> int:
    """Point-tile height for an (n, d) x (k, d) launch: the VMEM-fitted block,
    clamped DOWN to the largest power of two <= n (never past the point count;
    the old round-up overshot n and launched oversized tiles), floored at the
    128-lane minimum. Non-multiple-of-block n is handled by padding + masking
    in the kernel wrappers, so any returned size is legal. The pick always
    uses the fp32 accounting even for bf16 streams, so a run's tile height —
    and with it the partials/bound-state shapes — is precision-independent."""
    cap = pick_block_n(d, k, batched=batched)
    if n >= cap:
        return cap
    return max(128, 1 << (max(n, 1).bit_length() - 1))


def _ensure_batched(x, is_batched: bool, axis_size: int):
    return x if is_batched else jnp.broadcast_to(x[None], (axis_size,) + x.shape)


def _align(points: jax.Array, centroids: jax.Array, norms):
    """Centroids follow the point stream dtype (bf16 streaming streams both);
    norms default to an on-the-fly fp32 computation."""
    cents = centroids.astype(points.dtype)
    if norms is None:
        norms = point_norms(points)
    return cents, norms.astype(jnp.float32)


def seed_prologue(points: jax.Array, *, block_n: int | None = None,
                  interpret: bool | None = None):
    """One streaming pass over the dataset: (norms, tile centers, tile radii)
    at the seed-tile height — everything the gated round kernels cache."""
    _check_forced()
    n, d = points.shape
    if block_n is None:
        block_n = choose_block_n(n, d, 1, batched=True)
    if interpret is None:
        interpret = default_interpret()
    return seed_prologue_pallas(points, block_n=block_n, interpret=interpret)


def distance_min_update(points: jax.Array, centroids: jax.Array,
                        min_d2: jax.Array, *, norms: jax.Array | None = None,
                        resident_centroids: bool = True,
                        block_n: int | None = None,
                        interpret: bool | None = None):
    """One k-means++ seeding round: fused D^2 min-update + per-tile partials.

    Returns (new_min_d2 (n,), partials (n_tiles,)) with the tile height
    `choose_block_n(n, d, k)` — the same tile the two-level `tiled` sampler
    draws from. Under `jax.vmap` this dispatches to the batch-grid kernel
    (`distance_min_update_batched`), not a per-problem loop."""
    _check_forced()
    n, d = points.shape
    k = centroids.shape[0]
    user_block = block_n
    if block_n is None:
        block_n = choose_block_n(n, d, k)
    if interpret is None:
        interpret = default_interpret()
    centroids, norms = _align(points, centroids, norms)

    @custom_vmap
    def call(pts, cents, md, nrm):
        return distance_min_update_pallas(pts, nrm, cents, md,
                                          block_n=block_n,
                                          resident=resident_centroids,
                                          interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, pts, cents, md, nrm):
        pts = _ensure_batched(pts, in_batched[0], axis_size)
        cents = _ensure_batched(cents, in_batched[1], axis_size)
        md = _ensure_batched(md, in_batched[2], axis_size)
        nrm = _ensure_batched(nrm, in_batched[3], axis_size)
        # block_n=None re-picks the tile with the batch-grid VMEM accounting
        out = distance_min_update_batched(pts, cents, md, norms=nrm,
                                          block_n=user_block,
                                          interpret=interpret)
        return out, (True, True)

    return call(points, centroids, min_d2, norms)


def distance_min_update_batched(points: jax.Array, centroids: jax.Array,
                                min_d2: jax.Array, *,
                                norms: jax.Array | None = None,
                                block_n: int | None = None,
                                interpret: bool | None = None):
    """Batched seeding round: (B, n, d) x (B, k, d) -> ((B, n), (B, n_tiles))
    in one batch-grid kernel launch."""
    _check_forced()
    _, n, d = points.shape
    k = centroids.shape[1]
    if block_n is None:
        block_n = choose_block_n(n, d, k, batched=True)
    if interpret is None:
        interpret = default_interpret()
    centroids, norms = _align(points, centroids, norms)
    return distance_min_update_batched_pallas(points, norms, centroids,
                                              min_d2, block_n=block_n,
                                              interpret=interpret)


def distance_min_update_gated(points: jax.Array, centroids: jax.Array,
                              min_d2: jax.Array, norms: jax.Array,
                              center_d: jax.Array, dc: jax.Array,
                              margin: jax.Array, prev_partials: jax.Array,
                              prev_tile_max: jax.Array, active: jax.Array, *,
                              block_n: int,
                              resident_centroids: bool = True,
                              interpret: bool | None = None):
    """Bound-gated seeding round (two-level exact pruning).

    ``active``/``dc``/``margin`` come from `core.bounds.seed_gate` and
    ``center_d`` from the prologue; the mask is compacted here into the
    scalar-prefetched index map the gated kernel consumes, so inactive tiles
    are neither fetched nor computed and their outputs keep the previous
    round's (bitwise-identical) values, while inside active tiles the
    per-point bound short-circuits rows whose ``min_d2`` provably cannot
    improve. Returns (new_min_d2, partials, tile_max, pruned (n_tiles,),
    skipped). ``block_n`` is required: it must match the tile height of the
    carried bound state. Under `jax.vmap` this dispatches to the gated
    batch-grid kernel with per-problem compaction."""
    from repro.core import bounds as bnd

    _check_forced()
    n, d = points.shape
    if interpret is None:
        interpret = default_interpret()
    centroids = centroids.astype(points.dtype)
    norms = norms.astype(jnp.float32)
    grid = -(-n // block_n)
    ids, n_active = bnd.compact_ids(active)
    skipped = (grid - n_active).astype(jnp.int32)

    @custom_vmap
    def call(pts, cents, md, nrm, cd, dc_, mg, pp, ptm, ids_, nact):
        meta = jnp.stack([jnp.full((), n, jnp.int32), nact.astype(jnp.int32)])
        return distance_min_update_gated_pallas(
            pts, nrm, cents, md, cd, dc_, mg, pp, ptm, ids_, meta,
            block_n=block_n, resident=resident_centroids,
            interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, *args):
        args = [_ensure_batched(a, b, axis_size)
                for a, b in zip(args, in_batched)]
        pts, cents, md, nrm, cd, dc_, mg, pp, ptm, ids_, nact = args
        out = distance_min_update_gated_batched_pallas(
            pts, nrm, cents, md, cd, dc_, mg, pp, ptm, ids_, nact,
            block_n=block_n, interpret=interpret)
        return out, (True, True, True, True)

    new_md, partials, tile_max, pruned = call(
        points, centroids, min_d2, norms, center_d.astype(jnp.float32), dc,
        margin, prev_partials, prev_tile_max, ids, n_active)
    return new_md, partials, tile_max, pruned, skipped


def row_min_d2(points: jax.Array, idx: jax.Array, centroids: jax.Array,
               count: jax.Array, *, interpret: bool | None = None):
    """Scalar D^2 of row ``idx`` to the nearest of ``centroids[:count]`` —
    the rejection sampler's exact-p gather (O(d) bytes of the dataset per
    proposal, DMA-steered by the scalar-prefetched row index). count == 0
    returns +inf. Under `jax.vmap` (the engine's batched seeding) this
    dispatches to the pure-jnp twin — a (B,)-batch of single-row gathers has
    no kernel to win."""
    _check_forced()
    if interpret is None:
        interpret = default_interpret()

    @custom_vmap
    def call(pts, i, cents, cnt):
        return row_min_d2_pallas(pts, i, cents, cnt, interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, pts, i, cents, cnt):
        from repro.kernels.ref import row_min_d2_ref
        pts = _ensure_batched(pts, in_batched[0], axis_size)
        i = _ensure_batched(i, in_batched[1], axis_size)
        cents = _ensure_batched(cents, in_batched[2], axis_size)
        cnt = _ensure_batched(cnt, in_batched[3], axis_size)
        return jax.vmap(row_min_d2_ref)(pts, i, cents, cnt), True

    return call(points, jnp.asarray(idx, jnp.int32), centroids,
                jnp.asarray(count, jnp.int32))


def tile_cap(centers: jax.Array, radii: jax.Array, pending: jax.Array,
             count: jax.Array, *, interpret: bool | None = None):
    """(n_tiles,) per-tile rejection-envelope caps ``(dc_t + r_t)^2`` against
    the first ``count`` pending centroids — the movement-tightened envelope's
    one (n_tiles, pending) pass over the prologue's tile summaries (never
    rows). count == 0 returns +inf everywhere (no tightening). Under
    `jax.vmap` (the engine's batched seeding) this dispatches to the
    pure-jnp twin — the per-problem summary pass is accumulator-bound, not
    kernel-bound."""
    _check_forced()
    if interpret is None:
        interpret = default_interpret()

    @custom_vmap
    def call(cent, rad, pend, cnt):
        return tile_cap_pallas(cent, rad, pend, cnt, interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, cent, rad, pend, cnt):
        from repro.kernels.ref import tile_cap_ref
        cent = _ensure_batched(cent, in_batched[0], axis_size)
        rad = _ensure_batched(rad, in_batched[1], axis_size)
        pend = _ensure_batched(pend, in_batched[2], axis_size)
        cnt = _ensure_batched(cnt, in_batched[3], axis_size)
        return jax.vmap(tile_cap_ref)(cent, rad, pend, cnt), True

    return call(centers.astype(jnp.float32), radii.astype(jnp.float32),
                pending.astype(jnp.float32), jnp.asarray(count, jnp.int32))


def lloyd_assign(points: jax.Array, centroids: jax.Array, *,
                 norms: jax.Array | None = None, block_n: int | None = None,
                 interpret: bool | None = None):
    """Fused assignment + per-cluster partial sums/counts. Under `jax.vmap`
    this dispatches to the batch-grid kernel (`lloyd_assign_batched`)."""
    _check_forced()
    n, d = points.shape
    k = centroids.shape[0]
    user_block = block_n
    if block_n is None:
        block_n = choose_block_n(n, d, k)
    if interpret is None:
        interpret = default_interpret()
    centroids, norms = _align(points, centroids, norms)

    @custom_vmap
    def call(pts, cents, nrm):
        return lloyd_assign_pallas(pts, nrm, cents, block_n=block_n,
                                   interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, pts, cents, nrm):
        pts = _ensure_batched(pts, in_batched[0], axis_size)
        cents = _ensure_batched(cents, in_batched[1], axis_size)
        nrm = _ensure_batched(nrm, in_batched[2], axis_size)
        # block_n=None re-picks the tile with the batch-grid VMEM accounting
        out = lloyd_assign_batched(pts, cents, norms=nrm, block_n=user_block,
                                   interpret=interpret)
        return out, (True, True, True, True)

    return call(points, centroids, norms)


def lloyd_assign_batched(points: jax.Array, centroids: jax.Array, *,
                         norms: jax.Array | None = None,
                         block_n: int | None = None,
                         interpret: bool | None = None):
    """Batched Lloyd half-step: (B, n, d) x (B, k, d) -> per-problem
    (assignment, min_d2, sums, counts) in one batch-grid kernel launch."""
    _check_forced()
    _, n, d = points.shape
    k = centroids.shape[1]
    if block_n is None:
        block_n = choose_block_n(n, d, k, batched=True)
    if interpret is None:
        interpret = default_interpret()
    centroids, norms = _align(points, centroids, norms)
    return lloyd_assign_batched_pallas(points, norms, centroids,
                                       block_n=block_n, interpret=interpret)


def lloyd_assign_tiled(points: jax.Array, centroids: jax.Array, *,
                       norms: jax.Array | None = None,
                       block_n: int | None = None,
                       tps: int | None = None,
                       interpret: bool | None = None):
    """Bounded-Lloyd assignment half-step with per-tile scalars and
    hierarchical accumulators.

    Returns (assignment, min_d2, partials (n_tiles,), gaps (n_tiles,),
    super_sums (n_super, k, d), super_counts (n_super, k)) with
    ``n_super = ceil(n_tiles / core.bounds.tiles_per_super(n_tiles, tps))``
    — the ungated twin of `lloyd_assign_gated`, sharing its two-level
    reduction tree so bounded and unbounded fits compare bitwise. ``tps``
    overrides the super-tile fan-in heuristic (the autotuner's knob); the
    gated twin must be called with the SAME value so the carried super
    accumulator shapes agree. Under `jax.vmap` this dispatches to the
    batch-grid kernel."""
    from repro.core import bounds as bnd

    _check_forced()
    n, d = points.shape
    k = centroids.shape[0]
    if block_n is None:
        block_n = choose_block_n(n, d, k)
    bn = block_n
    tps = bnd.tiles_per_super(-(-n // bn), tps)
    if interpret is None:
        interpret = default_interpret()
    centroids, norms = _align(points, centroids, norms)

    @custom_vmap
    def call(pts, cents, nrm):
        return lloyd_assign_tiled_pallas(pts, nrm, cents, block_n=bn,
                                         tps=tps, interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, pts, cents, nrm):
        pts = _ensure_batched(pts, in_batched[0], axis_size)
        cents = _ensure_batched(cents, in_batched[1], axis_size)
        nrm = _ensure_batched(nrm, in_batched[2], axis_size)
        out = lloyd_assign_tiled_batched_pallas(pts, nrm, cents, block_n=bn,
                                                tps=tps, interpret=interpret)
        return out, (True,) * 6

    return call(points, centroids, norms)


def lloyd_assign_gated(points: jax.Array, centroids: jax.Array,
                       norms: jax.Array, delta: jax.Array,
                       thresh: jax.Array, absorb: jax.Array,
                       prev_assign: jax.Array, prev_min_d2: jax.Array,
                       prev_lb: jax.Array, prev_partials: jax.Array,
                       prev_gaps: jax.Array, prev_super_sums: jax.Array,
                       prev_super_counts: jax.Array, active: jax.Array, *,
                       block_n: int, tps: int | None = None,
                       interpret: bool | None = None):
    """Bound-gated assignment half-step (two-level exact Lloyd pruning).

    ``active`` is the (n_tiles,) bool mask from
    `core.bounds.assign_active_tiles`; it is EXPANDED to whole super-tiles
    here (the hierarchical accumulators alias at super granularity — see
    `core.bounds.expand_active_supers`) and compacted into the
    scalar-prefetched index map, so skipped tiles are neither fetched nor
    computed and all of their outputs keep the previous iteration's
    (bitwise-identical) values. ``delta``/``thresh``/``absorb`` (from
    `core.bounds.assign_point_scalars`) drive the per-point Hamerly prune
    inside computed tiles. Returns the `lloyd_assign_tiled` tuple plus
    (lb (n,), pruned (n_tiles,), skipped ()). ``block_n`` is required: it
    must match the tile height of the carried bound state. Under `jax.vmap`
    this dispatches to the gated batch-grid kernel with per-problem
    expansion + compaction."""
    from repro.core import bounds as bnd

    _check_forced()
    n, d = points.shape
    if interpret is None:
        interpret = default_interpret()
    centroids = centroids.astype(points.dtype)
    norms = norms.astype(jnp.float32)
    grid = -(-n // block_n)
    tps = bnd.tiles_per_super(grid, tps)
    active = bnd.expand_active_supers(active, tps)
    ids, n_active = bnd.compact_ids(active)
    skipped = (grid - n_active).astype(jnp.int32)

    @custom_vmap
    def call(pts, cents, nrm, dl, th, ab, pa, pmd, plb, pp, pg, pss, psc,
             ids_, nact):
        meta = jnp.stack([jnp.full((), n, jnp.int32), nact.astype(jnp.int32)])
        return lloyd_assign_gated_pallas(
            pts, nrm, cents, dl, th, ab, pa, pmd, plb, pp, pg, pss, psc,
            ids_, meta, block_n=block_n, tps=tps, interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, *args):
        args = [_ensure_batched(a, b, axis_size)
                for a, b in zip(args, in_batched)]
        (pts, cents, nrm, dl, th, ab, pa, pmd, plb, pp, pg, pss, psc,
         ids_, nact) = args
        out = lloyd_assign_gated_batched_pallas(
            pts, nrm, cents, dl, th, ab, pa, pmd, plb, pp, pg, pss, psc,
            ids_, nact, block_n=block_n, tps=tps, interpret=interpret)
        return out, (True,) * 8

    out = call(points, centroids, norms, delta.astype(jnp.float32),
               thresh.astype(jnp.float32), absorb.astype(jnp.float32),
               prev_assign, prev_min_d2, prev_lb, prev_partials, prev_gaps,
               prev_super_sums, prev_super_counts, ids, n_active)
    return out + (skipped,)


def ivf_scan(queries: jax.Array, points: jax.Array, norms: jax.Array,
             centers: jax.Array, radii: jax.Array, ids: jax.Array,
             n_active: jax.Array, *, k: int, block_n: int,
             gate: bool = True, interpret: bool | None = None):
    """Batched gated cluster-local exact scan (IVF serving's inner loop).

    ``ids``/``n_active`` are the per-query compacted probed-tile maps
    (`core.bounds.compact_ids` over the probed-list tile coverage);
    ``centers``/``radii`` the prologue's ball summaries at the SAME
    ``block_n``. Already batched over queries by its grid, so no
    custom_vmap rule is needed. Returns (dists (Q, k) fp32, rows (Q, k)
    int32 into the sorted layout, gate_skipped (Q,) int32)."""
    from repro.kernels.ivf_scan import ivf_scan_pallas

    _check_forced()
    if interpret is None:
        interpret = default_interpret()
    return ivf_scan_pallas(queries, points, norms.astype(jnp.float32),
                           centers, radii, ids, n_active, k=k,
                           block_n=block_n, gate=gate, interpret=interpret)


def ivf_adc_scan(queries: jax.Array, lut: jax.Array, qdots: jax.Array,
                 codes: jax.Array, labels: jax.Array, u: jax.Array,
                 centers: jax.Array, radii: jax.Array, ids: jax.Array,
                 n_active: jax.Array, *, k: int, block_n: int,
                 gate: bool = True, interpret: bool | None = None):
    """Batched gated PQ/ADC scan: per-query LUT + routing dots against
    streamed uint8 codes (n_sub bytes/row instead of 4d). ``centers``/
    ``radii`` must be the balls over the RECONSTRUCTED rows so the gate is
    exact for ADC scores. Same return triple as :func:`ivf_scan`."""
    from repro.kernels.ivf_scan import ivf_adc_scan_pallas

    _check_forced()
    if interpret is None:
        interpret = default_interpret()
    return ivf_adc_scan_pallas(queries, lut, qdots, codes, labels,
                               u.astype(jnp.float32), centers, radii, ids,
                               n_active, k=k, block_n=block_n, gate=gate,
                               interpret=interpret)
