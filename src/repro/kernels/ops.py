"""jit'd public wrappers around the Pallas kernels.

On a TPU backend the kernels compile to Mosaic; everywhere else they run in
interpret mode (Python evaluation of the kernel body — bit-correct, slow),
which is how this CPU container validates them. Block sizes are chosen so the
working set (points tile + resident centroids + accumulators + per-tile
partials) fits a v5e VMEM budget of ~64 MB with double buffering.

The wrappers carry a `custom_vmap` rule: `jax.vmap` over them dispatches to
the batch-grid kernel variants (one launch with a leading batch grid
dimension) instead of relying on the generic pallas batching rule — this is
what lets the engine's `seed_batched`/`fit_batched` vmap hit real batched
kernels with the VMEM budget accounted for.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.kernels.kmeans_distance import (distance_min_update_batched_pallas,
                                           distance_min_update_pallas)
from repro.kernels.lloyd_assign import (lloyd_assign_batched_pallas,
                                        lloyd_assign_pallas)

_VMEM_BUDGET = 48 * 1024 * 1024  # leave headroom out of ~64-128MB


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_block_n(d: int, k: int, *, dtype_bytes: int = 4,
                 max_block: int = 4096, batched: bool = False) -> int:
    """Largest power-of-two point-tile height whose double-buffered working set
    fits the VMEM budget. Accounted per grid step:

      2 x (bn, d) point tile           (double-buffered HBM->VMEM stream)
      (k, d) resident centroid block
      (bn, k) distance tile + ~4 per-point vectors
      fp32 accumulators: (k, d) sums + (k,) counts + the per-tile partial
        (the seeding kernel's thrust::reduce analogue)

    `batched=True` budgets the batch-grid kernels, whose centroid block is
    re-fetched per problem and therefore double-buffered like the point
    stream (one extra (k, d) operand block in flight)."""
    bn = max_block
    while bn > 128:
        working = dtype_bytes * (2 * bn * d + k * d + bn * k + 4 * bn)
        working += 4 * (k * d + k + 8)      # fp32 accumulators + partial
        if batched:
            working += dtype_bytes * k * d  # second centroid buffer
        if working <= _VMEM_BUDGET:
            return bn
        bn //= 2
    return 128


def choose_block_n(n: int, d: int, k: int, *, batched: bool = False) -> int:
    """Point-tile height for an (n, d) x (k, d) launch: the VMEM-fitted block,
    clamped DOWN to the largest power of two <= n (never past the point count;
    the old round-up overshot n and launched oversized tiles), floored at the
    128-lane minimum. Non-multiple-of-block n is handled by padding + masking
    in the kernel wrappers, so any returned size is legal."""
    cap = pick_block_n(d, k, batched=batched)
    if n >= cap:
        return cap
    return max(128, 1 << (max(n, 1).bit_length() - 1))


def _ensure_batched(x, is_batched: bool, axis_size: int):
    return x if is_batched else jnp.broadcast_to(x[None], (axis_size,) + x.shape)


def distance_min_update(points: jax.Array, centroids: jax.Array,
                        min_d2: jax.Array, *, resident_centroids: bool = True,
                        block_n: int | None = None,
                        interpret: bool | None = None):
    """One k-means++ seeding round: fused D^2 min-update + per-tile partials.

    Returns (new_min_d2 (n,), partials (n_tiles,)) with the tile height
    `choose_block_n(n, d, k)` — the same tile the two-level `tiled` sampler
    draws from. Under `jax.vmap` this dispatches to the batch-grid kernel
    (`distance_min_update_batched`), not a per-problem loop."""
    n, d = points.shape
    k = centroids.shape[0]
    user_block = block_n
    if block_n is None:
        block_n = choose_block_n(n, d, k)
    if interpret is None:
        interpret = not _on_tpu()

    @custom_vmap
    def call(pts, cents, md):
        return distance_min_update_pallas(pts, cents, md, block_n=block_n,
                                          resident=resident_centroids,
                                          interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, pts, cents, md):
        pts = _ensure_batched(pts, in_batched[0], axis_size)
        cents = _ensure_batched(cents, in_batched[1], axis_size)
        md = _ensure_batched(md, in_batched[2], axis_size)
        # block_n=None re-picks the tile with the batch-grid VMEM accounting
        out = distance_min_update_batched(pts, cents, md, block_n=user_block,
                                          interpret=interpret)
        return out, (True, True)

    return call(points, centroids, min_d2)


def distance_min_update_batched(points: jax.Array, centroids: jax.Array,
                                min_d2: jax.Array, *,
                                block_n: int | None = None,
                                interpret: bool | None = None):
    """Batched seeding round: (B, n, d) x (B, k, d) -> ((B, n), (B, n_tiles))
    in one batch-grid kernel launch."""
    _, n, d = points.shape
    k = centroids.shape[1]
    if block_n is None:
        block_n = choose_block_n(n, d, k, batched=True)
    if interpret is None:
        interpret = not _on_tpu()
    return distance_min_update_batched_pallas(points, centroids, min_d2,
                                              block_n=block_n,
                                              interpret=interpret)


def lloyd_assign(points: jax.Array, centroids: jax.Array, *,
                 block_n: int | None = None, interpret: bool | None = None):
    """Fused assignment + per-cluster partial sums/counts. Under `jax.vmap`
    this dispatches to the batch-grid kernel (`lloyd_assign_batched`)."""
    n, d = points.shape
    k = centroids.shape[0]
    user_block = block_n
    if block_n is None:
        block_n = choose_block_n(n, d, k)
    if interpret is None:
        interpret = not _on_tpu()

    @custom_vmap
    def call(pts, cents):
        return lloyd_assign_pallas(pts, cents, block_n=block_n,
                                   interpret=interpret)

    @call.def_vmap
    def _rule(axis_size, in_batched, pts, cents):
        pts = _ensure_batched(pts, in_batched[0], axis_size)
        cents = _ensure_batched(cents, in_batched[1], axis_size)
        # block_n=None re-picks the tile with the batch-grid VMEM accounting
        out = lloyd_assign_batched(pts, cents, block_n=user_block,
                                   interpret=interpret)
        return out, (True, True, True, True)

    return call(points, centroids)


def lloyd_assign_batched(points: jax.Array, centroids: jax.Array, *,
                         block_n: int | None = None,
                         interpret: bool | None = None):
    """Batched Lloyd half-step: (B, n, d) x (B, k, d) -> per-problem
    (assignment, min_d2, sums, counts) in one batch-grid kernel launch."""
    _, n, d = points.shape
    k = centroids.shape[1]
    if block_n is None:
        block_n = choose_block_n(n, d, k, batched=True)
    if interpret is None:
        interpret = not _on_tpu()
    return lloyd_assign_batched_pallas(points, centroids, block_n=block_n,
                                       interpret=interpret)
