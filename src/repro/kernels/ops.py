"""jit'd public wrappers around the Pallas kernels.

On a TPU backend the kernels compile to Mosaic; everywhere else they run in
interpret mode (Python evaluation of the kernel body — bit-correct, slow),
which is how this CPU container validates them. Block sizes are chosen so the
working set (points tile + resident centroids + accumulators) fits a v5e
VMEM budget of ~64 MB with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_distance import distance_min_update_pallas
from repro.kernels.lloyd_assign import lloyd_assign_pallas

_VMEM_BUDGET = 48 * 1024 * 1024  # leave headroom out of ~64-128MB


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_block_n(d: int, k: int, *, dtype_bytes: int = 4,
                 max_block: int = 4096) -> int:
    """Largest power-of-two point-tile height whose double-buffered working set
    (2 x points tile + resident centroids + (block_n, k) distance tile) fits."""
    bn = max_block
    while bn > 128:
        working = dtype_bytes * (2 * bn * d + k * d + bn * k + 4 * bn)
        if working <= _VMEM_BUDGET:
            return bn
        bn //= 2
    return 128


def choose_block_n(n: int, d: int, k: int) -> int:
    """Point-tile height for an (n, d) x (k, d) launch: the VMEM-fitted block,
    clamped DOWN to the largest power of two <= n (never past the point count;
    the old round-up overshot n and launched oversized tiles), floored at the
    128-lane minimum. Non-multiple-of-block n is handled by padding + masking
    in the kernel wrappers, so any returned size is legal."""
    cap = pick_block_n(d, k)
    if n >= cap:
        return cap
    return max(128, 1 << (max(n, 1).bit_length() - 1))


def distance_min_update(points: jax.Array, centroids: jax.Array,
                        min_d2: jax.Array, *, resident_centroids: bool = True,
                        block_n: int | None = None,
                        interpret: bool | None = None):
    """One k-means++ seeding round: fused D^2 min-update + per-tile partials."""
    n, d = points.shape
    k = centroids.shape[0]
    if block_n is None:
        block_n = choose_block_n(n, d, k)
    if interpret is None:
        interpret = not _on_tpu()
    return distance_min_update_pallas(points, centroids, min_d2,
                                      block_n=block_n,
                                      resident=resident_centroids,
                                      interpret=interpret)


def lloyd_assign(points: jax.Array, centroids: jax.Array, *,
                 block_n: int | None = None, interpret: bool | None = None):
    """Fused assignment + per-cluster partial sums/counts."""
    n, d = points.shape
    k = centroids.shape[0]
    if block_n is None:
        block_n = choose_block_n(n, d, k)
    if interpret is None:
        interpret = not _on_tpu()
    a, md, sums, counts = lloyd_assign_pallas(points, centroids,
                                              block_n=block_n,
                                              interpret=interpret)
    return a, md, sums, counts
