"""PQ flash-decode (Pallas TPU): decode attention over a PRODUCT-QUANTIZED
KV cache — the paper's k-means++ applied to the serving hot path.

Long-context decode is HBM-bound on KV-cache streaming (roofline §C:
codeqwen decode_32k reads a 2.2 TB bf16 cache per step). serve/kvquant.py
builds k-means++-seeded codebooks; this kernel computes attention DIRECTLY
over the uint8 codes, so HBM traffic per step is

    codes:  S * KH * n_sub        bytes   (vs  S * KH * hd * 2  for bf16)
    + the codebooks (n_sub, 256, dsub) — VMEM-RESIDENT across the whole
      grid: the paper's constant-memory insight a third time.

Reconstruction inside VMEM uses one-hot matmuls (codes -> one-hot(256) ->
@ codebook), the TPU-idiomatic replacement for a gather: the MXU does the
lookup. head_dim 128 / n_sub 16 => 16x less cache traffic.

Layout: grid (B, KH, nk); VMEM scratch carries (m, l, acc) for the G query
heads of one kv head across kv blocks (sequential innermost grid dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _reconstruct(codes_u8, cb):
    """codes (block_k, n_sub) uint8 + cb (n_sub, 256, dsub) -> (block_k, d).
    One-hot matmul per sub-space (MXU lookup, no gather)."""
    block_k, n_sub = codes_u8.shape
    n_codes = cb.shape[1]
    onehot = (codes_u8[:, :, None].astype(jnp.int32)
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_codes), 2))
    onehot = onehot.astype(jnp.float32)                  # (bk, n_sub, 256)
    # (n_sub, bk, 256) @ (n_sub, 256, dsub) -> (n_sub, bk, dsub)
    parts = jax.lax.dot_general(
        onehot.transpose(1, 0, 2), cb,
        (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    return parts.transpose(1, 0, 2).reshape(block_k, -1)  # (bk, n_sub*dsub)


def _kernel(len_ref, q_ref, kc_ref, vc_ref, kcb_ref, vcb_ref, o_ref,
            m_scr, l_scr, acc_scr, *, block_k: int, scale: float):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[0]
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = k_pos < cache_len                                # (1, block_k)

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
        k = _reconstruct(kc_ref[0, 0], kcb_ref[0])          # (bk, hd)
        v = _reconstruct(vc_ref[0, 0], vcb_ref[0])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, _NEG_INF)                    # (G, bk)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_k", "interpret"))
def pq_decode_attention(q: jax.Array, k_codes: jax.Array, v_codes: jax.Array,
                        k_cb: jax.Array, v_cb: jax.Array,
                        cache_len: jax.Array, *, block_k: int = 512,
                        interpret: bool | None = None) -> jax.Array:
    """Single-token decode attention over PQ codes.

    q        (B, 1, H, hd)       — current query
    k_codes  (B, S, KH, n_sub) uint8 ; v_codes same
    k_cb     (KH, n_sub, 256, dsub)  ; v_cb same (per-kv-head codebooks)
    cache_len () int32           — valid positions
    Returns (B, 1, H, hd).
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    B, _, H, hd = q.shape
    S, KH = k_codes.shape[1], k_codes.shape[2]
    n_sub = k_codes.shape[3]
    G = H // KH
    scale = hd ** -0.5
    pad = (-S) % block_k
    kc = jnp.pad(k_codes, ((0, 0), (0, pad), (0, 0), (0, 0))) \
        .transpose(0, 2, 1, 3)                               # (B, KH, S, n_sub)
    vc = jnp.pad(v_codes, ((0, 0), (0, pad), (0, 0), (0, 0))) \
        .transpose(0, 2, 1, 3)
    qh = q.reshape(B, 1, KH, G, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, KH, G, hd)
    nk = kc.shape[2] // block_k
    len_arr = jnp.asarray([cache_len], jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, scale=scale),
        grid=(B, KH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (0,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, n_sub),
                         lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, n_sub),
                         lambda b, h, ik: (b, h, ik, 0)),
            # codebooks: VMEM-RESIDENT across the grid (constant-memory
            # analogue — index_map pins the block)
            pl.BlockSpec((1, n_sub, 256, hd // n_sub),
                         lambda b, h, ik: (h, 0, 0, 0)),
            pl.BlockSpec((1, n_sub, 256, hd // n_sub),
                         lambda b, h, ik: (h, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(len_arr, qh, kc, vc, k_cb, v_cb)
    return out.reshape(B, 1, H, hd)      # (B, KH, G, hd): H = kh*G + g


def hbm_bytes_model(B: int, S: int, KH: int, hd: int, n_sub: int) -> dict:
    """Per-step cache traffic: PQ codes vs bf16 KV (for §Perf C)."""
    bf16 = 2 * B * S * KH * hd * 2
    pq = 2 * B * S * KH * n_sub + 2 * KH * n_sub * 256 * (hd // n_sub) * 4
    return {"bf16_cache_bytes": bf16, "pq_bytes": pq,
            "compression": bf16 / pq}
