"""Fused k-means++ seeding-round kernels (the paper's hot spot, TPU-native).

One seeding round updates every point's D^2 against the newest centroid(s) and
produces the normalization term sum(D^2).

CUDA (paper)                         ->  TPU (this kernel)
---------------------------------------------------------------------------
1 thread per point, 1024/block       ->  grid over (block_n, d) point tiles;
                                         the 8x128 VPU lanes are the threads
centroids in CONSTANT memory         ->  centroid block VMEM-RESIDENT across
(broadcast cache)                        all grid steps (index_map -> (0, 0))
points in TEXTURE memory             ->  points streamed HBM->VMEM by the
(read-only, cached, spatial)             Pallas pipeline (double-buffered),
                                         read exactly ONCE (fused pass)
thrust::reduce for sum(D^2)          ->  per-tile partial sums accumulated
                                         on-chip; final tiny jnp.sum outside

Three bandwidth/FLOP optimizations compose on top of that mapping:

* **norm caching** — ``||x||^2`` is computed ONCE per dataset by the tiny
  prologue kernel (`seed_prologue_pallas`) and streamed as an extra fp32
  ``(n,)`` input, dropping d FLOPs/point/round from every round kernel.
* **mixed-precision streaming** — the point tiles and centroid block keep
  their input dtype all the way into the MXU (`dot_general` with
  ``preferred_element_type=f32``), so bf16 inputs stream at half the HBM
  bytes with fp32 accumulation and fp32 cached norms. fp32 inputs take
  bitwise the same path as before (the products of bf16 values are exact in
  fp32, so this refactor changes no fp32 results).
* **exact tile skipping** — the gated variants take a scalar-prefetched
  compacted active-tile index map (`core.bounds.compact_ids`): grid step i
  streams tile ``ids[i]``; steps past ``n_active`` revisit the last active
  tile (already VMEM-resident, no HBM fetch) and are compute-gated off by
  ``pl.when``. Skipped tiles are neither computed nor fetched — their
  ``min_d2`` / partial / tile-max outputs keep the previous round's values
  via ``input_output_aliases``, which is exact (see ``core.bounds``).

The matmul form ``||x||^2 - 2 x.c + ||c||^2`` puts the inner product on the
MXU (d up to 4096 in our integrations vs d=2 in the paper's figures).

Raw kernels take ``interpret`` EXPLICITLY: ``kernels.ops`` is the single
place the on-TPU/off-TPU default is chosen — calling a raw kernel without it
is a TypeError, not a silent interpreted run on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the ONE definition of the fine-level (per-point) seeding prune test — the
# pure-JAX gate model in core.engine evaluates the same function, so model
# and kernel prune decisions share a single source of truth
from repro.core.bounds import seed_point_prune as _seed_point_prune


def tile_d2(x_raw, c_raw, xn):
    """(block_n, k) matmul-form D^2 for one point tile — THE shared round
    math (lloyd_assign imports it too, so the bitwise fused==pallas parity
    has a single source of truth).

    ``x_raw``/``c_raw`` keep their input dtype into the MXU (bf16 streams at
    half width; fp32 is bitwise the historical path) with fp32 accumulation;
    ``xn`` is the cached fp32 ``||x||^2`` block.
    """
    cf = c_raw.astype(jnp.float32)
    cn = jnp.sum(cf * cf, axis=1)                  # (k_new,)
    dots = jax.lax.dot_general(x_raw, c_raw, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    return jnp.maximum(xn[:, None] - 2.0 * dots + cn[None, :], 0.0)


def _tile_d2_min(x_raw, c_raw, xn):
    """min over the centroid block of `tile_d2` (the seeding-round fold)."""
    return jnp.min(tile_d2(x_raw, c_raw, xn), axis=1)


def _round_kernel(n_valid_ref, pts_ref, norms_ref, cents_ref, md_ref,
                  out_md_ref, partial_ref, *, block_n: int):
    """Grid step i processes point rows [i*block_n, (i+1)*block_n)."""
    i = pl.program_id(0)
    md = md_ref[...].astype(jnp.float32)           # (block_n,)
    xn = norms_ref[...].astype(jnp.float32)        # (block_n,) cached
    new_md = jnp.minimum(md, _tile_d2_min(pts_ref[...], cents_ref[...], xn))

    # mask padded tail rows (they must not contribute to the reduction)
    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    new_md = jnp.where(valid, new_md, 0.0)

    out_md_ref[...] = new_md.astype(out_md_ref.dtype)
    partial_ref[0] = jnp.sum(new_md)               # thrust::reduce analogue


@functools.partial(jax.jit,
                   static_argnames=("block_n", "resident", "interpret"))
def distance_min_update_pallas(points: jax.Array, norms: jax.Array,
                               centroids: jax.Array, min_d2: jax.Array, *,
                               block_n: int, resident: bool, interpret: bool):
    """Returns (new_min_d2 (n,), partials (grid,)). sum(partials) == sum(D^2).

    ``norms`` is the cached fp32 ``||x||^2`` (n,) from the prologue.
    resident=True keeps the centroid block pinned in VMEM across grid steps
    (constant-memory analogue). resident=False re-indexes the centroid block
    every step, modelling the global-memory variant's repeated fetch.
    """
    n, d = points.shape
    k_new = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    md = jnp.pad(min_d2, (0, pad), constant_values=jnp.inf)
    n_valid = jnp.array([n], jnp.int32)

    if resident:
        cent_spec = pl.BlockSpec((k_new, d), lambda i: (0, 0))
    else:
        # index_map depends on i mod 1 == 0 block but non-constant lambda forces
        # a refetch each grid step (two-pass global-memory behaviour).
        cent_spec = pl.BlockSpec((k_new, d), lambda i: (0, i * 0))

    out_md, partials = pl.pallas_call(
        functools.partial(_round_kernel, block_n=block_n),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # n_valid (scalar-ish)
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),  # streamed points
            pl.BlockSpec((block_n,), lambda i: (i,)),      # cached ||x||^2
            cent_spec,                                      # centroids
            pl.BlockSpec((block_n,), lambda i: (i,)),      # min_d2 in
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),      # min_d2 out
            pl.BlockSpec((1,), lambda i: (i,)),            # per-tile partial
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids, md)
    return out_md[:n], partials


# ---------------------------------------------------------------------------
# bound-gated variant (exact tile skipping via scalar-prefetched index map)
# ---------------------------------------------------------------------------


def _round_kernel_gated(ids_ref, meta_ref, pts_ref, norms_ref, cents_ref,
                        md_ref, cdist_ref, dc_ref, margin_ref, pp_ref,
                        ptm_ref, pz_ref, out_md_ref, partial_ref,
                        tmax_ref, pruned_ref, *, block_n: int):
    """Grid step i streams tile ``ids[i]``; steps >= n_active are no-ops.

    ``meta`` = [n_valid, n_active]. ``pp_ref``/``ptm_ref``/``pz_ref``
    (previous partials / tile-max / a zeros buffer) are never read — they
    exist to carry the aliased buffers the skipped tiles' outputs fall back
    to. Inside an active tile the FINE level of the bound fires per point:
    rows whose carried ``min_d2`` provably cannot improve (``(dc −
    center_d)² >= md`` with margin — see ``core.bounds.seed_point_prune``)
    keep it verbatim, a value-noop by construction that the ``pruned``
    output counts (the modelled per-point FLOP saving).
    """
    del pp_ref, ptm_ref, pz_ref
    i = pl.program_id(0)

    @pl.when(i < meta_ref[1])
    def _compute():
        t = ids_ref[i]                             # the REAL tile id
        md = md_ref[...].astype(jnp.float32)
        xn = norms_ref[...].astype(jnp.float32)
        row = t * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
        valid = row < meta_ref[0]
        prune = valid & _seed_point_prune(md, cdist_ref[...], dc_ref[0],
                                          margin_ref[0])
        upd = jnp.minimum(md, _tile_d2_min(pts_ref[...], cents_ref[...], xn))
        new_md = jnp.where(prune, md, upd)
        new_md = jnp.where(valid, new_md, 0.0)

        out_md_ref[...] = new_md.astype(out_md_ref.dtype)
        partial_ref[0] = jnp.sum(new_md)
        tmax_ref[0] = jnp.max(new_md)              # bound state for next round
        pruned_ref[0] = jnp.sum(prune.astype(jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("block_n", "resident", "interpret"))
def distance_min_update_gated_pallas(points: jax.Array, norms: jax.Array,
                                     centroids: jax.Array, min_d2: jax.Array,
                                     center_d: jax.Array, dc: jax.Array,
                                     margin: jax.Array,
                                     prev_partials: jax.Array,
                                     prev_tile_max: jax.Array,
                                     ids: jax.Array, meta: jax.Array, *,
                                     block_n: int, resident: bool,
                                     interpret: bool):
    """Bound-gated seeding round. Returns (new_min_d2 (n,), partials (grid,),
    tile_max (grid,), pruned (grid,)).

    ``ids``/``meta=[n_valid, n_active]`` come from `core.bounds.compact_ids`:
    only the first n_active grid steps fetch + compute (each visiting active
    tile ids[i]); every output block of a skipped tile keeps the aliased
    previous-round value, which the bound proves is bitwise what a full
    recompute would write. ``center_d``/``dc``/``margin`` are the fine-level
    inputs from the prologue and `core.bounds.seed_gate`; ``pruned`` counts
    per-point short-circuits per tile (zero for skipped tiles via a donated
    zeros buffer).
    """
    n, d = points.shape
    k_new = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), (0, pad))
    md = jnp.pad(min_d2.astype(jnp.float32), (0, pad),
                 constant_values=jnp.inf)
    cd = jnp.pad(center_d.astype(jnp.float32), (0, pad))

    if resident:
        cent_spec = pl.BlockSpec((k_new, d), lambda i, ids, meta: (0, 0))
    else:
        cent_spec = pl.BlockSpec((k_new, d), lambda i, ids, meta: (0, i * 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                      # ids, meta
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, ids, meta: (ids[i], 0)),
            pl.BlockSpec((block_n,), lambda i, ids, meta: (ids[i],)),
            cent_spec,
            pl.BlockSpec((block_n,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((block_n,), lambda i, ids, meta: (ids[i],)),  # c_d
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),   # dc
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),   # margin
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),   # prev part
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),   # prev tmax
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),   # zeros
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),
            pl.BlockSpec((1,), lambda i, ids, meta: (ids[i],)),
        ],
    )
    out_md, partials, tile_max, pruned = pl.pallas_call(
        functools.partial(_round_kernel_gated, block_n=block_n),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        # skipped tiles reuse their prior min_d2 / partials / tile-max and
        # report zero pruned points (the donated zeros buffer)
        input_output_aliases={5: 0, 9: 1, 10: 2, 11: 3},
        interpret=interpret,
    )(ids, meta, pts, nrm, centroids, md, cd, dc.astype(jnp.float32),
      margin.astype(jnp.float32), prev_partials.astype(jnp.float32),
      prev_tile_max.astype(jnp.float32), jnp.zeros((grid,), jnp.float32))
    return out_md[:n], partials, tile_max, pruned


# ---------------------------------------------------------------------------
# single-row gather + distance: the rejection sampler's exact-p evaluation
# ---------------------------------------------------------------------------


def _row_min_d2_kernel(meta_ref, row_ref, cents_ref, out_ref):
    """One grid step: D^2 of the prefetched row to the nearest of the first
    ``meta[1]`` centroid slots (the rejection loop's pending buffer; slots
    past the count are +inf-masked, so an empty pending block yields +inf and
    ``min(q, +inf) == q`` keeps the accept ratio bitwise at 1).

    ``meta = [row_idx, count]`` rides the scalar-prefetch channel: the row
    index steers the (1, d) point block's DMA — the kernel touches O(d) bytes
    of the dataset, not a tile — which is the whole point of the rejection
    sampler (per-proposal work independent of n)."""
    x = row_ref[...].astype(jnp.float32)           # (1, d)
    c = cents_ref[...].astype(jnp.float32)         # (m, d)
    diff = x - c                                   # broadcast over slots
    d2 = jnp.sum(diff * diff, axis=1)              # (m,)
    slot = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 0)
    out_ref[0] = jnp.min(jnp.where(slot < meta_ref[1], d2, jnp.inf))


@functools.partial(jax.jit, static_argnames=("interpret",))
def row_min_d2_pallas(points: jax.Array, idx: jax.Array,
                      centroids: jax.Array, count: jax.Array, *,
                      interpret: bool) -> jax.Array:
    """Scalar fp32 D^2 of row ``idx`` to the nearest of ``centroids[:count]``.

    The diff-square form (not the matmul/cached-norm form): a single row has
    no MXU tile to win back, and the rejection sampler's exactness needs only
    p <= q — which ``min`` with the stale weight enforces regardless of the
    fp form (see kernels.ref.row_min_d2_ref, the bitwise oracle)."""
    n, d = points.shape
    m = centroids.shape[0]
    meta = jnp.stack([idx.astype(jnp.int32), count.astype(jnp.int32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                      # meta = [row, count]
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, meta: (meta[0], 0)),  # the row
            pl.BlockSpec((m, d), lambda i, meta: (0, 0)),        # pending
        ],
        out_specs=pl.BlockSpec((1,), lambda i, meta: (0,)),
    )
    out = pl.pallas_call(
        _row_min_d2_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=interpret,
    )(meta, points, centroids.astype(points.dtype))
    return out[0]


# ---------------------------------------------------------------------------
# per-tile envelope cap: the movement-tightened rejection envelope's
# (n_tiles, pending) pass over tile summaries — never rows
# ---------------------------------------------------------------------------


def _tile_cap_kernel(meta_ref, cents_ref, radii_ref, pend_ref, out_ref):
    """One grid step: per-tile envelope caps from the tile BALLS only.

    ``meta = [count]`` rides the scalar-prefetch channel. For every tile ball
    (center_t, r_t) the triangle inequality gives ``d(x_i, c) <= d(center_t,
    c) + r_t`` for each of its rows, so ``(min_c d(center_t, c) + r_t)^2``
    over the first ``count`` pending slots dominates every row's CURRENT
    min_d2 — the Raff bound the rejection sampler shrinks its stale envelope
    with between refreshes. Slots >= count are +inf-masked; count == 0
    yields +inf everywhere (a tightening no-op, which is what keeps
    refresh_block=1 bitwise on the flat path)."""
    c = cents_ref[...].astype(jnp.float32)         # (n_tiles, d)
    p = pend_ref[...].astype(jnp.float32)          # (m, d)
    diff = c[:, None, :] - p[None, :, :]
    d2 = jnp.sum(diff * diff, axis=2)              # (n_tiles, m)
    slot = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    dc2 = jnp.min(jnp.where(slot < meta_ref[0], d2, jnp.inf), axis=1)
    cap = (jnp.sqrt(dc2) + radii_ref[...].astype(jnp.float32)) ** 2
    out_ref[...] = jnp.where(meta_ref[0] > 0, cap, jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tile_cap_pallas(centers: jax.Array, radii: jax.Array,
                    pending: jax.Array, count: jax.Array, *,
                    interpret: bool) -> jax.Array:
    """(n_tiles,) fp32 per-tile envelope caps ``(dc_t + r_t)^2`` against
    ``pending[:count]`` — O(n_tiles * count * d) over tile summaries (the
    whole point: no row is touched; see kernels.ref.tile_cap_ref)."""
    t, d = centers.shape
    m = pending.shape[0]
    meta = count.astype(jnp.int32)[None]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                      # meta = [count]
        grid=(1,),
        in_specs=[
            pl.BlockSpec((t, d), lambda i, meta: (0, 0)),  # tile centers
            pl.BlockSpec((t,), lambda i, meta: (0,)),      # tile radii
            pl.BlockSpec((m, d), lambda i, meta: (0, 0)),  # pending block
        ],
        out_specs=pl.BlockSpec((t,), lambda i, meta: (0,)),
    )
    out = pl.pallas_call(
        _tile_cap_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=interpret,
    )(meta, centers, radii.astype(jnp.float32), pending)
    return out


# ---------------------------------------------------------------------------
# prologue kernel: cached norms + tile centroid-balls, ONE pass over the data
# ---------------------------------------------------------------------------


def _prologue_kernel(n_valid_ref, pts_ref, norms_ref, center_ref, radius_ref,
                     cdist_ref, *, block_n: int):
    i = pl.program_id(0)
    x = pts_ref[...].astype(jnp.float32)           # (block_n, d)
    xn = jnp.sum(x * x, axis=1)

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    norms_ref[...] = jnp.where(valid, xn, 0.0)

    cnt = jnp.sum(valid.astype(jnp.float32))
    xm = jnp.where(valid[:, None], x, 0.0)
    ctr = jnp.sum(xm, axis=0) / jnp.maximum(cnt, 1.0)
    center_ref[0, :] = ctr
    d2c = jnp.sum((x - ctr[None, :]) ** 2, axis=1)
    radius_ref[0] = jnp.sqrt(jnp.max(jnp.where(valid, d2c, 0.0)))
    # per-point distance to the ball center — the fine-level seeding bound
    cdist_ref[...] = jnp.where(valid, jnp.sqrt(d2c), 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def seed_prologue_pallas(points: jax.Array, *, block_n: int, interpret: bool):
    """ONE streaming pass computing everything the round kernels cache:
    (norms (n,) fp32, tile centers (grid, d) fp32, tile radii (grid,) fp32,
    center_d (n,) fp32 — each point's distance to its tile ball center, the
    per-point seeding bound)."""
    n, d = points.shape
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    n_valid = jnp.array([n], jnp.int32)

    norms, centers, radii, center_d = pl.pallas_call(
        functools.partial(_prologue_kernel, block_n=block_n),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid, d), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts)
    return norms[:n], centers, radii, center_d[:n]


# ---------------------------------------------------------------------------
# batch-grid variants (multi-tenant clustering: B independent problems)
# ---------------------------------------------------------------------------


def _round_kernel_batched(n_valid_ref, pts_ref, norms_ref, cents_ref, md_ref,
                          out_md_ref, partial_ref, *, block_n: int):
    """Grid step (b, i) processes rows [i*block_n, (i+1)*block_n) of problem b.

    Same math as `_round_kernel`; the leading singleton axis is problem b's
    block. The centroid block is re-fetched per problem (it differs per b) but
    stays resident across the inner i steps."""
    i = pl.program_id(1)
    md = md_ref[0].astype(jnp.float32)             # (block_n,)
    xn = norms_ref[0].astype(jnp.float32)
    new_md = jnp.minimum(md, _tile_d2_min(pts_ref[0], cents_ref[0], xn))

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    new_md = jnp.where(valid, new_md, 0.0)

    out_md_ref[0] = new_md.astype(out_md_ref.dtype)
    partial_ref[0, 0] = jnp.sum(new_md)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def distance_min_update_batched_pallas(points: jax.Array, norms: jax.Array,
                                       centroids: jax.Array,
                                       min_d2: jax.Array, *,
                                       block_n: int, interpret: bool):
    """Batched seeding round over B independent problems in ONE launch.

    points (B, n, d), norms (B, n), centroids (B, k_new, d), min_d2 (B, n) ->
    (new_min_d2 (B, n), partials (B, n_tiles)). Row b of the outputs is
    bitwise what `distance_min_update_pallas` computes for problem b — the
    grid just gains a leading batch dimension, so the many-tenant path pays
    one kernel launch instead of B."""
    B, n, d = points.shape
    k_new = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    md = jnp.pad(min_d2, ((0, 0), (0, pad)), constant_values=jnp.inf)
    n_valid = jnp.array([n], jnp.int32)

    out_md, partials = pl.pallas_call(
        functools.partial(_round_kernel_batched, block_n=block_n),
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, k_new, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, nrm, centroids, md)
    return out_md[:, :n], partials


def _round_kernel_gated_batched(ids_ref, nact_ref, nv_ref, pts_ref, norms_ref,
                                cents_ref, md_ref, cdist_ref, dc_ref,
                                margin_ref, pp_ref, ptm_ref, pz_ref,
                                out_md_ref, partial_ref, tmax_ref,
                                pruned_ref, *, block_n: int):
    """Grid step (b, i) streams tile ids[b, i] of problem b; steps past
    problem b's n_active are no-ops (per-problem compaction)."""
    del pp_ref, ptm_ref, pz_ref
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i < nact_ref[b])
    def _compute():
        t = ids_ref[b, i]
        md = md_ref[0].astype(jnp.float32)
        xn = norms_ref[0].astype(jnp.float32)
        row = t * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
        valid = row < nv_ref[0]
        prune = valid & _seed_point_prune(md, cdist_ref[0], dc_ref[0, 0],
                                          margin_ref[0, 0])
        upd = jnp.minimum(md, _tile_d2_min(pts_ref[0], cents_ref[0], xn))
        new_md = jnp.where(prune, md, upd)
        new_md = jnp.where(valid, new_md, 0.0)

        out_md_ref[0] = new_md.astype(out_md_ref.dtype)
        partial_ref[0, 0] = jnp.sum(new_md)
        tmax_ref[0, 0] = jnp.max(new_md)
        pruned_ref[0, 0] = jnp.sum(prune.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def distance_min_update_gated_batched_pallas(
        points: jax.Array, norms: jax.Array, centroids: jax.Array,
        min_d2: jax.Array, center_d: jax.Array, dc: jax.Array,
        margin: jax.Array, prev_partials: jax.Array,
        prev_tile_max: jax.Array, ids: jax.Array, n_active: jax.Array, *,
        block_n: int, interpret: bool):
    """Batch-grid bound-gated round: (B, n, d) problems, per-problem compacted
    active-tile maps ids (B, n_tiles) / n_active (B,). Row b is bitwise
    `distance_min_update_gated_pallas` on problem b."""
    B, n, d = points.shape
    k_new = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pad)))
    md = jnp.pad(min_d2.astype(jnp.float32), ((0, 0), (0, pad)),
                 constant_values=jnp.inf)
    cd = jnp.pad(center_d.astype(jnp.float32), ((0, 0), (0, pad)))
    nv = jnp.array([n], jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                      # ids, n_active, n_valid
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1, block_n, d),
                         lambda b, i, ids, na, nv: (b, ids[b, i], 0)),
            pl.BlockSpec((1, block_n),
                         lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, k_new, d), lambda b, i, ids, na, nv: (b, 0, 0)),
            pl.BlockSpec((1, block_n),
                         lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, block_n),
                         lambda b, i, ids, na, nv: (b, ids[b, i])),   # c_d
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n),
                         lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
            pl.BlockSpec((1, 1), lambda b, i, ids, na, nv: (b, ids[b, i])),
        ],
    )
    out_md, partials, tile_max, pruned = pl.pallas_call(
        functools.partial(_round_kernel_gated_batched, block_n=block_n),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
        ],
        input_output_aliases={6: 0, 10: 1, 11: 2, 12: 3},
        interpret=interpret,
    )(ids.astype(jnp.int32), n_active.astype(jnp.int32), nv, pts, nrm,
      centroids, md, cd, dc.astype(jnp.float32), margin.astype(jnp.float32),
      prev_partials.astype(jnp.float32), prev_tile_max.astype(jnp.float32),
      jnp.zeros((B, grid), jnp.float32))
    return out_md[:, :n], partials, tile_max, pruned
