"""Fused k-means++ seeding-round kernel (the paper's hot spot, TPU-native).

One seeding round updates every point's D^2 against the newest centroid(s) and
produces the normalization term sum(D^2).

CUDA (paper)                         ->  TPU (this kernel)
---------------------------------------------------------------------------
1 thread per point, 1024/block       ->  grid over (block_n, d) point tiles;
                                         the 8x128 VPU lanes are the threads
centroids in CONSTANT memory         ->  centroid block VMEM-RESIDENT across
(broadcast cache)                        all grid steps (index_map -> (0, 0))
points in TEXTURE memory             ->  points streamed HBM->VMEM by the
(read-only, cached, spatial)             Pallas pipeline (double-buffered),
                                         read exactly ONCE (fused pass)
thrust::reduce for sum(D^2)          ->  per-tile partial sums accumulated
                                         on-chip; final tiny jnp.sum outside

The matmul form  ||x||^2 - 2 x.c + ||c||^2  puts the inner product on the MXU
(d up to 4096 in our integrations vs d=2 in the paper's figures).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_kernel(n_valid_ref, pts_ref, cents_ref, md_ref, out_md_ref,
                  partial_ref, *, block_n: int):
    """Grid step i processes point rows [i*block_n, (i+1)*block_n)."""
    i = pl.program_id(0)
    x = pts_ref[...].astype(jnp.float32)           # (block_n, d)
    c = cents_ref[...].astype(jnp.float32)         # (k_new, d) resident
    md = md_ref[...].astype(jnp.float32)           # (block_n,)

    xn = jnp.sum(x * x, axis=1, keepdims=True)     # (block_n, 1)
    cn = jnp.sum(c * c, axis=1)                    # (k_new,)
    # MXU matmul: (block_n, d) @ (d, k_new)
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xn - 2.0 * dots + cn[None, :], 0.0)  # (block_n, k_new)
    new_md = jnp.minimum(md, jnp.min(d2, axis=1))

    # mask padded tail rows (they must not contribute to the reduction)
    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    new_md = jnp.where(valid, new_md, 0.0)

    out_md_ref[...] = new_md.astype(out_md_ref.dtype)
    partial_ref[0] = jnp.sum(new_md)               # thrust::reduce analogue


@functools.partial(jax.jit,
                   static_argnames=("block_n", "resident", "interpret"))
def distance_min_update_pallas(points: jax.Array, centroids: jax.Array,
                               min_d2: jax.Array, *, block_n: int = 1024,
                               resident: bool = True, interpret: bool = True):
    """Returns (new_min_d2 (n,), partials (grid,)). sum(partials) == sum(D^2).

    resident=True keeps the centroid block pinned in VMEM across grid steps
    (constant-memory analogue). resident=False re-indexes the centroid block
    every step, modelling the global-memory variant's repeated fetch.
    """
    n, d = points.shape
    k_new = centroids.shape[0]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    md = jnp.pad(min_d2, (0, pad), constant_values=jnp.inf)
    n_valid = jnp.array([n], jnp.int32)

    if resident:
        cent_spec = pl.BlockSpec((k_new, d), lambda i: (0, 0))
    else:
        # index_map depends on i mod 1 == 0 block but non-constant lambda forces
        # a refetch each grid step (two-pass global-memory behaviour).
        cent_spec = pl.BlockSpec((k_new, d), lambda i: (0, i * 0))

    out_md, partials = pl.pallas_call(
        functools.partial(_round_kernel, block_n=block_n),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # n_valid (scalar-ish)
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),  # streamed points
            cent_spec,                                      # centroids
            pl.BlockSpec((block_n,), lambda i: (i,)),      # min_d2 in
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),      # min_d2 out
            pl.BlockSpec((1,), lambda i: (i,)),            # per-tile partial
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, centroids, md)
    return out_md[:n], partials


# ---------------------------------------------------------------------------
# batch-grid variant (multi-tenant clustering: B independent problems)
# ---------------------------------------------------------------------------


def _round_kernel_batched(n_valid_ref, pts_ref, cents_ref, md_ref, out_md_ref,
                          partial_ref, *, block_n: int):
    """Grid step (b, i) processes rows [i*block_n, (i+1)*block_n) of problem b.

    Same math as `_round_kernel`; the leading singleton axis is problem b's
    block. The centroid block is re-fetched per problem (it differs per b) but
    stays resident across the inner i steps."""
    i = pl.program_id(1)
    x = pts_ref[0].astype(jnp.float32)             # (block_n, d)
    c = cents_ref[0].astype(jnp.float32)           # (k_new, d)
    md = md_ref[0].astype(jnp.float32)             # (block_n,)

    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xn - 2.0 * dots + cn[None, :], 0.0)
    new_md = jnp.minimum(md, jnp.min(d2, axis=1))

    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    valid = row < n_valid_ref[0]
    new_md = jnp.where(valid, new_md, 0.0)

    out_md_ref[0] = new_md.astype(out_md_ref.dtype)
    partial_ref[0, 0] = jnp.sum(new_md)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def distance_min_update_batched_pallas(points: jax.Array, centroids: jax.Array,
                                       min_d2: jax.Array, *,
                                       block_n: int = 1024,
                                       interpret: bool = True):
    """Batched seeding round over B independent problems in ONE launch.

    points (B, n, d), centroids (B, k_new, d), min_d2 (B, n) ->
    (new_min_d2 (B, n), partials (B, n_tiles)). Row b of the outputs is
    bitwise what `distance_min_update_pallas` computes for problem b — the
    grid just gains a leading batch dimension, so the many-tenant path pays
    one kernel launch instead of B."""
    B, n, d = points.shape
    k_new = centroids.shape[1]
    pad = (-n) % block_n
    grid = (n + pad) // block_n
    pts = jnp.pad(points, ((0, 0), (0, pad), (0, 0)))
    md = jnp.pad(min_d2, ((0, 0), (0, pad)), constant_values=jnp.inf)
    n_valid = jnp.array([n], jnp.int32)

    out_md, partials = pl.pallas_call(
        functools.partial(_round_kernel_batched, block_n=block_n),
        grid=(B, grid),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, k_new, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n + pad), jnp.float32),
            jax.ShapeDtypeStruct((B, grid), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid, pts, centroids, md)
    return out_md[:, :n], partials
