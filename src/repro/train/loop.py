"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests/examples):
  * auto-resume: newest committed checkpoint + data pipeline ``skip_to`` —
    a restarted cohort continues exactly where the dead one stopped;
  * preemption save: SIGTERM/SIGINT triggers an immediate blocking
    checkpoint then a clean exit (the standard TPU-pod preemption contract);
  * periodic async checkpoints every ``save_every`` steps;
  * straggler / slow-step monitor: per-step wall time EWMA + variance; steps
    slower than mu + k*sigma are logged with their step index — at pod scale
    this feeds the re-scheduling policy (here: a log line + counter);
  * NaN-loss circuit breaker (skip update, count; abort after a run of them).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    save_every: int = 200
    log_every: int = 10
    straggler_k: float = 3.0      # flag steps slower than mu + k*sigma
    max_nan_steps: int = 5


@dataclasses.dataclass
class StepStats:
    ewma: float = 0.0
    var: float = 0.0
    n: int = 0
    stragglers: int = 0

    def update(self, dt: float, k: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.n == 0:
            self.ewma, self.var = dt, 0.0
        slow = (self.n > 10
                and dt > self.ewma + k * max(self.var, 1e-12) ** 0.5)
        a = 0.05
        d = dt - self.ewma
        self.ewma += a * d
        self.var = (1 - a) * (self.var + a * d * d)
        self.n += 1
        self.stragglers += int(slow)
        return slow


def train(state: Any,
          train_step: Callable[[Any, dict], tuple[Any, dict]],
          pipeline,
          loop_cfg: LoopConfig,
          *,
          ckpt: Optional[CheckpointManager] = None,
          resume: bool = True,
          state_shardings: Any = None,
          log_fn: Callable[[str], None] = print) -> tuple[Any, dict]:
    """Runs up to loop_cfg.total_steps. Returns (final_state, summary)."""
    start_step = 0
    if ckpt is not None and resume and ckpt.latest_step() is not None:
        start_step, state = ckpt.restore(state, shardings=state_shardings)
        log_fn(f"[train] resumed from step {start_step}")
    pipeline.skip_to(start_step)

    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True
        log_fn(f"[train] signal {signum}: preemption save requested")

    old_term = signal.signal(signal.SIGTERM, _handler)
    old_int = signal.signal(signal.SIGINT, _handler)

    stats = StepStats()
    losses: list[float] = []
    nan_run = 0
    step = start_step
    try:
        it = iter(pipeline)
        while step < loop_cfg.total_steps:
            step_idx, batch = next(it)
            assert step_idx == step, (step_idx, step)
            t0 = time.perf_counter()
            new_state, metrics = train_step(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0

            if np.isnan(loss) or np.isinf(loss):
                nan_run += 1
                log_fn(f"[train] step {step}: NaN/inf loss — update SKIPPED "
                       f"({nan_run}/{loop_cfg.max_nan_steps})")
                if nan_run >= loop_cfg.max_nan_steps:
                    raise FloatingPointError("persistent NaN loss")
            else:
                nan_run = 0
                state = new_state
                losses.append(loss)

            if stats.update(dt, loop_cfg.straggler_k):
                log_fn(f"[train] step {step}: STRAGGLER {dt*1e3:.0f}ms "
                       f"(ewma {stats.ewma*1e3:.0f}ms)")
            if step % loop_cfg.log_every == 0:
                log_fn(f"[train] step {step} loss {loss:.4f} "
                       f"{dt*1e3:.0f}ms lr {float(metrics.get('lr', 0)):.2e}")

            step += 1
            if ckpt is not None and (step % loop_cfg.save_every == 0):
                ckpt.save(step, state)
            if preempted["flag"]:
                if ckpt is not None:
                    ckpt.save(step, state, blocking=True)
                    log_fn(f"[train] preemption checkpoint at step {step}")
                break
    finally:
        pipeline.stop()
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)
        if ckpt is not None:
            ckpt.wait()

    summary = {"final_step": step, "losses": losses,
               "stragglers": stats.stragglers,
               "mean_step_ms": stats.ewma * 1e3,
               "preempted": preempted["flag"]}
    return state, summary
