"""repro.train — fault-tolerant training loop."""
from repro.train.loop import LoopConfig, StepStats, train

__all__ = ["LoopConfig", "StepStats", "train"]
