"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.
38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]"""
from repro.configs.common import ArchConfig

FULL = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    attn_every=6,                       # 6 groups of 6 + 2 tail mamba layers
    tie_embeddings=True,
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512, head_dim=16,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, conv_width=4,
    attn_every=2, ssm_chunk=16,
    tie_embeddings=True,
    supports_long_context=True,
)
