"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2. 32L d_model=4096 32H
(GQA kv=8) expert d_ff=6400 vocab=32064. [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.common import ArchConfig

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064,
    n_experts=16, n_experts_per_tok=2, moe_d_ff=6400,
)

SMOKE = ArchConfig(
    name="phi3.5-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
    n_experts=4, n_experts_per_tok=2, moe_d_ff=96, capacity_factor=8.0,
)
