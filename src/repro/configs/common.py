"""Architecture config dataclass shared by all 10 assigned archs + the paper's
own k-means workload config. Everything the model builders / sharding rules /
input_specs need is derivable from these fields."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # -- attention extras --
    sliding_window: int = 0        # 0 = none; gemma2 local layers
    alt_local_global: bool = False # gemma2: even layers local, odd global
    attn_softcap: float = 0.0      # gemma2 attention logit softcap
    logit_softcap: float = 0.0     # gemma2 final logit softcap
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) dims

    # -- MoE --
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0              # routed-expert hidden dim (if != d_ff)
    capacity_factor: float = 1.25
    moe_chunk: int = 32_768        # tokens per dispatch chunk (0 = all at once)
    expert_pad: int = 16           # pad expert arrays so EP divides the mesh
    moe_dispatch: str = "gather"   # gather (GSPMD baseline) | a2a (shard_map
                                   # all-to-all — §Perf hillclimb A)

    # -- SSM / hybrid --
    ssm_state: int = 0             # mamba2 d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    attn_every: int = 0            # zamba2: shared attn block period
    rwkv_head_dim: int = 64

    # -- enc-dec (whisper) --
    encoder_layers: int = 0
    encoder_seq: int = 0           # precomputed frame embeddings length

    # -- vlm --
    vision_tokens: int = 0         # patch embeddings per example (stub frontend)

    # -- performance variants (§Perf hillclimb; defaults = paper-faithful) --
    seq_shard: bool = False        # sequence-parallel residual stream
                                   # (Korthikanti SP): activations sharded
                                   # over "model" between blocks
    serve_dtype: str = ""          # cast float params for serving ("bfloat16")
    attn_stub: bool = False        # measurement-only: replace attention with
                                   # a linear-cost stand-in to ATTRIBUTE the
                                   # HBM traffic of attention (never used for
                                   # real runs — see EXPERIMENTS.md §Perf B)

    # -- numerics / training --
    post_norms: bool = False       # gemma2 pre+post sandwich norms
    embed_scale: bool = False      # gemma: scale embeddings by sqrt(d_model)
    act: str = "silu"              # mlp gate activation (gemma: gelu)
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    remat: bool = True
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    ssm_chunk: int = 128

    # which input shapes this arch supports (long_500k only for sub-quadratic)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, 128)

    @property
    def padded_experts(self) -> int:
        """Expert arrays padded so the EP dim divides the model axis (e.g.
        qwen2's 60 experts -> 64). Pad experts receive no tokens: the router
        has only n_experts logits."""
        return pad_to(self.n_experts, self.expert_pad) if self.n_experts else 0

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Approximate parameter count N (used for 6ND model-FLOPs)."""
        d, f, V = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":   # rwkv6
            per = 4 * d * d + d * d // 2 + 3 * d * f // 1  # r,k,v,g,o + ffn
            per = 5 * d * d + 2 * d * f
            return emb + self.n_layers * per
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.family == "moe":
            fe = self.moe_d_ff or f
            moe = self.n_experts * 3 * d * fe + self.n_shared_experts * 3 * d * fe
            per = attn + moe
        elif self.family == "hybrid":
            din, ds, H = self.d_inner, self.ssm_state, self.n_ssm_heads
            mamba = 2 * d * din + 2 * d * ds + d * H + din * d
            per = mamba  # shared attn counted once below
            return emb + self.n_layers * per + (attn + 3 * d * f) + 2 * d * d
        else:
            per = attn + 3 * d * f
        n = emb + self.n_layers * per
        if self.family == "encdec":
            n += self.encoder_layers * (attn + 2 * d * f)
        return n

    def active_param_count(self) -> int:
        """N_active for MoE (6*N_active*D model-FLOPs)."""
        if self.family != "moe":
            return self.param_count()
        d, V = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        fe = self.moe_d_ff or self.d_ff
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        act = (self.n_experts_per_tok + self.n_shared_experts) * 3 * d * fe
        return V * d * 2 + self.n_layers * (attn + act)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}
