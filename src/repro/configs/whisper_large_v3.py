"""whisper-large-v3 [audio] — enc-dec backbone; conv/mel frontend STUB
(input_specs provides precomputed frame embeddings). 32L d_model=1280 20H
(kv=20) d_ff=5120 vocab=51866. [arXiv:2212.04356; unverified]"""
from repro.configs.common import ArchConfig

FULL = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51866,
    encoder_layers=32, encoder_seq=1500,
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    encoder_layers=2, encoder_seq=32,
)
