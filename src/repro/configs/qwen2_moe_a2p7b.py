"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4. 24L d_model=2048 16H
(GQA kv=16) expert d_ff=1408 vocab=151936. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.common import ArchConfig

FULL = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=151936,
    n_experts=60, n_experts_per_tok=4, n_shared_experts=4, moe_d_ff=1408,
)

SMOKE = ArchConfig(
    name="qwen2-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64, vocab=512,
    n_experts=6, n_experts_per_tok=4, n_shared_experts=2, moe_d_ff=64,
    capacity_factor=8.0,
)
