"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (patch frontend STUB:
input_specs provides precomputed patch embeddings + 3D positions).
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. [arXiv:2409.12191]"""
from repro.configs.common import ArchConfig

FULL = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, head_dim=128,
    mrope_sections=(16, 24, 24),       # t/h/w splits of head_dim//2
    rope_theta=1_000_000.0,
    vision_tokens=1024,
)

SMOKE = ArchConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16,
    mrope_sections=(4, 2, 2),
    vision_tokens=8,
)
