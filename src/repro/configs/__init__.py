"""repro.configs — one module per assigned architecture (FULL + SMOKE) +
the paper's own k-means workload config; registry.get_config resolves
--arch names; specs.input_specs builds ShapeDtypeStruct stand-ins."""
from repro.configs.registry import ARCH_NAMES, get_config, get_shape, supported_shapes

__all__ = ["ARCH_NAMES", "get_config", "get_shape", "supported_shapes"]
