"""gemma2-2b [dense] — local+global alternating attention, logit softcaps,
sandwich norms, GeGLU. 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
[arXiv:2408.00118; hf]"""
from repro.configs.common import ArchConfig

FULL = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256_000, head_dim=256,
    sliding_window=4096, alt_local_global=True,
    attn_softcap=50.0, logit_softcap=30.0,
    post_norms=True, embed_scale=True, act="gelu",
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="gemma2-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16,
    sliding_window=32, alt_local_global=True,
    attn_softcap=50.0, logit_softcap=30.0,
    post_norms=True, embed_scale=True, act="gelu",
    tie_embeddings=True,
)
