"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell — the
dry-run lowers against these; nothing is allocated."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig, ShapeConfig
from repro.models.registry import get_model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _extras(cfg: ArchConfig, B: int, S: int):
    ex = {}
    if cfg.family == "encdec":
        ex["encoder_feats"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.family == "vlm":
        ex["vision_embeds"] = _sds((B, cfg.vision_tokens, cfg.d_model),
                                   jnp.bfloat16)
        ex["vision_mask"] = _sds((B, S), jnp.bool_)
        ex["positions"] = _sds((B, 3, S), jnp.int32)
    return ex


def train_specs(cfg: ArchConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
            **_extras(cfg, B, S)}


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": _sds((B, S), jnp.int32), **_extras(cfg, B, S)}


def cache_specs(cfg: ArchConfig, B: int, S_max: int):
    model = get_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(B, S_max))


def decode_specs(cfg: ArchConfig, shape: ShapeConfig):
    """serve_step: one new token against a cache of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"token": _sds((B, 1), jnp.int32),
             "cache": cache_specs(cfg, B, S)}
    if cfg.family == "vlm":
        specs["positions"] = _sds((B, 3, 1), jnp.int32)
    return specs


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Returns (kind, specs) for the cell's step function."""
    if shape.kind == "train":
        return "train", train_specs(cfg, shape)
    if shape.kind == "prefill":
        return "prefill", prefill_specs(cfg, shape)
    if shape.kind == "decode":
        return "decode", decode_specs(cfg, shape)
    raise ValueError(shape.kind)
