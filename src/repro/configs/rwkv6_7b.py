"""rwkv6-7b "Finch" [ssm] — attention-free, data-dependent decay.
32L d_model=4096 d_ff=14336 vocab=65536. [arXiv:2404.05892; hf]"""
from repro.configs.common import ArchConfig

FULL = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=14336,
    vocab=65536, rwkv_head_dim=64,
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=128, vocab=512,
    rwkv_head_dim=16,
    supports_long_context=True,
)
