"""codeqwen1.5-7b [dense] — qwen1.5-arch. 32L d_model=4096 32H (GQA kv=32)
d_ff=13440 vocab=92416. [hf:Qwen/CodeQwen1.5-7B; hf]
(qwen1.5's attention QKV bias omitted — noted in DESIGN.md)"""
from repro.configs.common import ArchConfig

FULL = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab=92416, rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="codeqwen-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    rope_theta=1_000_000.0,
)
