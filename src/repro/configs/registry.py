"""--arch lookup: full + smoke configs for the 10 assigned architectures."""
from __future__ import annotations

from repro.configs import (codeqwen1p5_7b, deepseek_7b, gemma2_2b, granite_8b,
                           phi3p5_moe, qwen2_moe_a2p7b, qwen2_vl_7b, rwkv6_7b,
                           whisper_large_v3, zamba2_1p2b)
from repro.configs.common import SHAPES, ArchConfig, ShapeConfig

_MODULES = {
    "zamba2-1.2b": zamba2_1p2b,
    "deepseek-7b": deepseek_7b,
    "gemma2-2b": gemma2_2b,
    "granite-8b": granite_8b,
    "codeqwen1.5-7b": codeqwen1p5_7b,
    "whisper-large-v3": whisper_large_v3,
    "phi3.5-moe-42b-a6.6b": phi3p5_moe,
    "qwen2-moe-a2.7b": qwen2_moe_a2p7b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "rwkv6-7b": rwkv6_7b,
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str, *, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return _MODULES[name].SMOKE if smoke else _MODULES[name].FULL


def supported_shapes(cfg: ArchConfig) -> list[str]:
    """All archs run train_4k / prefill_32k / decode_32k; long_500k needs
    sub-quadratic attention (SSM / hybrid) — skips recorded in DESIGN.md."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        shapes.append("long_500k")
    return shapes


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
