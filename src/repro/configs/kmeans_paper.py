"""The paper's own workload: k-means++ seeding over N points in d dims.
The paper evaluates d=2, N = 1M..10M, k = 10..100; `FULL` mirrors that and
`SMOKE` is the CPU-sized version the benchmarks sweep."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class KmeansConfig:
    name: str
    n_points: int
    dim: int
    k: int
    max_iters: int = 25


FULL = KmeansConfig(name="kmeans-paper", n_points=4_000_000, dim=2, k=50)
SMOKE = KmeansConfig(name="kmeans-smoke", n_points=8_192, dim=2, k=16)
