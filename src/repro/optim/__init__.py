"""repro.optim — AdamW + schedules + gradient compression (error feedback)."""
from repro.optim.adamw import (AdamWConfig, OptState, apply, clip_by_global_norm,
                               global_norm, init, schedule)
from repro.optim.grad_compress import (CompressConfig, EFState, compress_with_ef,
                                       init_ef, roundtrip, wire_bytes)

__all__ = ["AdamWConfig", "OptState", "apply", "init", "schedule",
           "global_norm", "clip_by_global_norm", "CompressConfig", "EFState",
           "compress_with_ef", "init_ef", "roundtrip", "wire_bytes"]
