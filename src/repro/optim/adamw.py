"""AdamW optimizer (pytree-functional, no external deps) + LR schedules.

The optimizer state is a pytree with the same structure as the params, so the
per-param PartitionSpecs from `repro.models.partition` apply verbatim to the
first/second moments — sharded optimizer state for free (ZeRO-1-style when the
params themselves are TP-sharded; the DP axes hold replicated state, which is
the standard v5e-pod configuration).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0          # global-norm clip; 0 disables
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac * lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def apply(cfg: AdamWConfig, params, grads, state: OptState,
          *, decay_mask=None):
    """One AdamW update. Returns (new_params, new_state, metrics).

    decay_mask: optional pytree of bools — True where weight decay applies
    (default: every tensor with ndim >= 2, the usual no-decay-on-norms rule).
    """
    step = state.step + 1
    lr = schedule(cfg, step)
    gn = global_norm(grads)
    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, wd):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if wd:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_wd = treedef.flatten_up_to(decay_mask)
    out = [upd(p, g, m, v, wd)
           for p, g, m, v, wd in zip(flat_p, flat_g, flat_m, flat_v, flat_wd)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gn}
    return new_p, OptState(new_m, new_v, step), metrics
