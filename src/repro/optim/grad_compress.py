"""Gradient compression for the DP all-reduce, with error feedback.

Two codecs:

* ``int8`` — per-tensor symmetric linear quantization (the industry default;
  4x fewer bytes on the wire than fp32, 2x vs bf16).
* ``kmeans`` — non-uniform codebook quantization: 1-D k-means over the
  gradient values, seeded with k-means++ (THE PAPER'S ALGORITHM used as a
  distributed-training feature). Gradients are heavy-tailed, so a k-means
  codebook at 4 bits matches int8's error at half the bits — the seeding
  quality (paper's contribution) is what makes few-iteration Lloyd viable
  per step.

Both use error feedback (Seide et al. 2014): the quantization residual is
added to the next step's gradient, so compression error does not accumulate
as bias. ``compress -> all-reduce codes? No:`` the codec here compresses the
*local* gradient before the all-reduce and decompresses after; with psum of
quantized values the wire format stays dense but 1-2 bytes/elt. (True
code-domain all-reduce needs all-to-all regrouping; see DESIGN.md §Beyond.)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    codec: str = "int8"         # none | int8 | kmeans
    kmeans_bits: int = 4
    kmeans_iters: int = 4       # Lloyd refinement steps per tensor per step
    sample: int = 4096          # values subsampled for codebook fitting


class EFState(NamedTuple):
    residual: Any               # pytree like grads (fp32)


def init_ef(grads_shape) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape))


# ---------------------------------------------------------------------------
# codecs (per-tensor)
# ---------------------------------------------------------------------------

def _int8_roundtrip(g: jax.Array):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _kmeans_roundtrip(g: jax.Array, *, bits: int, iters: int, sample: int,
                      key: jax.Array):
    """1-D k-means codebook quantization, k-means++-seeded (repro.core)."""
    from repro.core.kmeanspp import kmeanspp

    flat = g.reshape(-1)
    n = flat.shape[0]
    k = 1 << bits
    take = min(sample, n)
    # deterministic strided subsample (cheap, unbiased enough for a codebook)
    stride = max(n // take, 1)
    sub = flat[::stride][:take, None]                       # (take, 1)
    code = kmeanspp(key, sub, k, variant="fused").centroids  # (k, 1)

    def lloyd_1d(code, _):
        d = jnp.abs(sub - code[:, 0][None, :])              # (take, k)
        a = jnp.argmin(d, axis=1)
        sums = jax.ops.segment_sum(sub[:, 0], a, num_segments=k)
        cnt = jax.ops.segment_sum(jnp.ones_like(sub[:, 0]), a, num_segments=k)
        new = jnp.where(cnt > 0, sums / jnp.maximum(cnt, 1), code[:, 0])
        return new[:, None], None

    code, _ = jax.lax.scan(lloyd_1d, code, None, length=iters)
    cb = jnp.sort(code[:, 0])
    # quantize all values: nearest codebook entry via searchsorted on midpoints
    mids = (cb[1:] + cb[:-1]) / 2
    idx = jnp.searchsorted(mids, flat)
    return cb[idx].reshape(g.shape)


def roundtrip(cfg: CompressConfig, g: jax.Array, key: jax.Array) -> jax.Array:
    """Quantize-dequantize g (what the wire would carry)."""
    g = g.astype(jnp.float32)
    if cfg.codec == "none":
        return g
    if cfg.codec == "int8":
        return _int8_roundtrip(g)
    if cfg.codec == "kmeans":
        return _kmeans_roundtrip(g, bits=cfg.kmeans_bits,
                                 iters=cfg.kmeans_iters, sample=cfg.sample,
                                 key=key)
    raise ValueError(f"unknown codec {cfg.codec!r}")


# ---------------------------------------------------------------------------
# error-feedback wrapper
# ---------------------------------------------------------------------------

def compress_with_ef(cfg: CompressConfig, grads, ef: EFState, key: jax.Array):
    """Returns (compressed_grads, new_ef). compressed = Q(g + residual);
    residual' = (g + residual) - compressed."""
    leaves, treedef = jax.tree.flatten(grads)
    res = treedef.flatten_up_to(ef.residual)
    keys = jax.random.split(key, len(leaves))
    outs, new_res = [], []
    for g, r, k in zip(leaves, res, keys):
        tgt = g.astype(jnp.float32) + r
        q = roundtrip(cfg, tgt, k)
        outs.append(q.astype(g.dtype))
        new_res.append(tgt - q)
    return treedef.unflatten(outs), EFState(treedef.unflatten(new_res))


def wire_bytes(cfg: CompressConfig, grads) -> int:
    """Bytes/element the codec puts on the DP all-reduce wire (for roofline)."""
    n = sum(int(jnp.size(g)) for g in jax.tree.leaves(grads))
    if cfg.codec == "none":
        return 4 * n
    if cfg.codec == "int8":
        return n
    if cfg.codec == "kmeans":
        return (cfg.kmeans_bits * n) // 8
    raise ValueError(cfg.codec)
