"""RWKV6 full model (attention-free SSM family). Decode carries per-layer
(shift tokens + wkv state) — O(1) memory per token, so long_500k runs natively."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models import layers as L
from repro.models import rwkv6 as R
from repro.models.sharding import constrain


def init_params(key, cfg: ArchConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)

    def layer_init(k):
        return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                "mix": R.rwkv6_init(k, cfg)}

    stacked = jax.vmap(layer_init)(keys[:cfg.n_layers])
    return {
        "layers": stacked,
        "embed": L.embed_init(keys[-1], (cfg.padded_vocab, cfg.d_model)),
        "ln_in": jnp.ones((cfg.d_model,), jnp.float32),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": L.embed_init(keys[-2], (cfg.padded_vocab, cfg.d_model)),
    }


def forward(params, cfg: ArchConfig, batch):
    tokens = batch["tokens"]
    dt = cfg.compute_dtype
    h = L.embed_lookup(params["embed"], tokens, dt)
    h = L.rms_norm(h, params["ln_in"], eps=cfg.norm_eps)
    h = constrain(h, "batch", None, None)

    def body(h, p):
        def inner(h, p):
            y, _ = R.rwkv6_time_mix(p["mix"],
                                    L.rms_norm(h, p["ln1"], eps=cfg.norm_eps),
                                    cfg)
            h = constrain(h + y, "batch", None, None)
            y, _ = R.rwkv6_channel_mix(p["mix"],
                                       L.rms_norm(h, p["ln2"],
                                                  eps=cfg.norm_eps), cfg)
            return constrain(h + y, "batch", None, None)
        if cfg.remat:
            inner = jax.checkpoint(inner)
        return inner(h, p), None

    h, _ = jax.lax.scan(body, h, params["layers"])
    h = L.rms_norm(h, params["ln_f"], eps=cfg.norm_eps)
    logits = L.unembed(h, params["unembed"], cap=cfg.logit_softcap)
    return constrain(logits, "batch", None, "model")


def loss_fn(params, cfg: ArchConfig, batch):
    return L.cross_entropy(forward(params, cfg, batch), batch["labels"],
                           vocab=cfg.vocab)


def init_cache(cfg: ArchConfig, B: int, S_max: int = 0):
    st = R.rwkv6_state_init(cfg, B)
    return {
        "state": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), st),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ArchConfig, batch, *, cache_len: Optional[int] = None):
    """Prompt pass; returns (last logits, recurrent state cache)."""
    tokens = batch["tokens"]
    dt = cfg.compute_dtype
    h = L.embed_lookup(params["embed"], tokens, dt)
    h = L.rms_norm(h, params["ln_in"], eps=cfg.norm_eps)

    def body(h, p):
        x1 = L.rms_norm(h, p["ln1"], eps=cfg.norm_eps)
        y, (tm_x, wkv) = R.rwkv6_time_mix(p["mix"], x1, cfg)
        h = h + y
        x2 = L.rms_norm(h, p["ln2"], eps=cfg.norm_eps)
        y, cm_x = R.rwkv6_channel_mix(p["mix"], x2, cfg)
        h = h + y
        return h, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}

    h, states = jax.lax.scan(body, h, params["layers"])
    hl = L.rms_norm(h[:, -1:], params["ln_f"], eps=cfg.norm_eps)
    logits = L.unembed(hl, params["unembed"], cap=cfg.logit_softcap)
    cache = {"state": states, "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits[:, 0], cache


def decode_step(params, cfg: ArchConfig, token, cache, **_):
    dt = cfg.compute_dtype
    h = L.embed_lookup(params["embed"], token, dt)
    h = L.rms_norm(h, params["ln_in"], eps=cfg.norm_eps)

    def body(h, xs):
        p, st = xs
        x1 = L.rms_norm(h, p["ln1"], eps=cfg.norm_eps)
        y, (tm_x, wkv) = R.rwkv6_time_mix_decode(p["mix"], x1, cfg,
                                                 st["tm_x"], st["wkv"])
        h = h + y
        x2 = L.rms_norm(h, p["ln2"], eps=cfg.norm_eps)
        y, cm_x = R.rwkv6_channel_mix(p["mix"], x2, cfg, x_prev=st["cm_x"])
        h = h + y
        return h, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}

    h, states = jax.lax.scan(body, h, (params["layers"], cache["state"]))
    h = L.rms_norm(h, params["ln_f"], eps=cfg.norm_eps)
    logits = L.unembed(h, params["unembed"], cap=cfg.logit_softcap)
    return logits[:, 0], {"state": states, "pos": cache["pos"] + 1}
