"""Mixture-of-Experts FFN with capacity-based top-k dispatch (GShard-style,
static shapes — pjit/EP friendly) + k-means++ router initialization (the
paper's technique as a first-class training feature).

Dispatch is gather/scatter by expert slot (not the (S, E, C) one-hot einsum,
whose mask alone is O(S*E*C) memory): a cumsum over the top-k one-hot gives
each token its position-in-expert; tokens beyond capacity are dropped
(standard GShard behaviour, capacity_factor controls the slack).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.common import ArchConfig
from repro.models.layers import dense_init, mlp_init, mlp_apply


def moe_init(key, cfg: ArchConfig):
    d = cfg.d_model
    fe = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    Ep = cfg.padded_experts          # sharding-friendly (pads get no tokens)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E)),
        "experts_wi": dense_init(ks[1], (Ep, d, fe)),
        "experts_wg": dense_init(ks[2], (Ep, d, fe)),
        "experts_wo": dense_init(ks[3], (Ep, fe, d),
                                 scale=1.0 / (2 * max(cfg.n_layers, 1)) ** 0.5),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, fe * cfg.n_shared_experts,
                               cfg.n_layers)
    return p


def _capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.n_experts_per_tok
            / cfg.n_experts)
    return max(8, ((c + 7) // 8) * 8)          # pad to 8 for TPU lanes


def moe_apply(p, x, cfg: ArchConfig):
    """x (B, S, d) -> (y (B, S, d), aux with load-balance stats).

    Dispatch is CHUNKED along the SEQUENCE dim (cfg.moe_chunk ~= tokens per
    chunk): each chunk is dispatched and combined independently, so the
    cross-shard token gather GSPMD emits for expert parallelism is bounded by
    chunk*d bytes instead of the whole batch (1M tokens x 4k d_model would
    otherwise all-gather GBs per layer). Chunking along S — NOT along the
    flattened token dim — keeps the lax.map axis unsharded while the batch
    dim stays data-parallel inside every chunk (a scan over a sharded dim
    would make GSPMD replicate the expert compute on every data shard).
    Capacity is per-chunk — the standard grouped-dispatch approximation."""
    B, S, d = x.shape
    target = max((cfg.moe_chunk // max(B, 1)) if cfg.moe_chunk else S, 1)
    chunk_s = S
    if target < S:  # largest divisor of S that is <= target
        for c in range(min(target, S), 0, -1):
            if S % c == 0:
                chunk_s = c
                break
    nc = S // chunk_s
    if nc == 1:
        y, aux = _moe_chunk(p, x.reshape(B * S, d), cfg)
    else:
        xs = x.reshape(B, nc, chunk_s, d).swapaxes(0, 1)   # (nc, B, cs, d)
        ys, auxs = jax.lax.map(
            lambda xc: _moe_chunk(p, xc.reshape(B * chunk_s, d), cfg), xs)
        y = ys.reshape(nc, B, chunk_s, d).swapaxes(0, 1)
        aux = jax.tree.map(jnp.mean, auxs)
    return y.reshape(B, S, d), aux


def _route(p, xf, cfg: ArchConfig, C: int):
    """Top-k routing + slotting for a token block xf (n, d).

    Returns (gate_vals (n,K) f32, keep (n,K) bool, slot_e, slot_c (n*K,),
    slot_src (Ep, C) int32, probs, gate_idx). The router matmul is fp32
    (standard practice): top-k sits on a decision boundary, bf16 reduction
    noise flips experts between batched-forward and single-token-decode paths.
    """
    n, _ = xf.shape
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    Ep = cfg.padded_experts
    logits = xf.astype(jnp.float32) @ p["router"]                 # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                 # (n, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, k) inside its expert queue (gate_idx < E <= Ep,
    # so pad experts never receive a token)
    onehot = jax.nn.one_hot(gate_idx, Ep, dtype=jnp.int32)        # (n, K, Ep)
    flat = onehot.reshape(n * K, Ep)
    pos = jnp.cumsum(flat, axis=0) - flat                         # exclusive
    pos_in_e = jnp.sum(pos * flat, axis=-1).reshape(n, K)
    keep = pos_in_e < C

    slot_e = gate_idx.reshape(-1)                                  # (n*K,)
    slot_c = jnp.where(keep.reshape(-1), pos_in_e.reshape(-1), C)  # C = drop
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32), K)
    slot_src = jnp.full((Ep, C + 1), n, jnp.int32)                 # n = pad row
    slot_src = slot_src.at[slot_e, slot_c].set(src)[:, :C]         # (Ep, C)
    return gate_vals, keep, slot_e, slot_c, slot_src, probs, gate_idx


def _experts_ffn(expert_in, wi, wg, wo, dt):
    h = jnp.einsum("ecd,edf->ecf", expert_in, wi.astype(dt))
    g = jnp.einsum("ecd,edf->ecf", expert_in, wg.astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * h
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))


def _combine(expert_out, gate_vals, keep, slot_e, slot_c, n, C, dt):
    Ep = expert_out.shape[0]
    d = expert_out.shape[-1]
    out_pad = jnp.concatenate(
        [expert_out.reshape(Ep * C, d), jnp.zeros((1, d), dt)], axis=0)
    flat_slot = jnp.where(keep.reshape(-1),
                          slot_e * C + slot_c, Ep * C)             # (n*K,)
    K = gate_vals.shape[1]
    per_k = out_pad[flat_slot].reshape(n, K, d)
    return jnp.sum(per_k * gate_vals[..., None].astype(dt), axis=1)


def _aux_stats(cfg, probs, gate_idx, keep, *, psum_axes=None):
    """Switch-style load balance. lb = E * sum(me * ce) is NONLINEAR in the
    per-token means, so under shard_map `me`/`ce` are psum-averaged across
    shards BEFORE the product — bitwise-matching the global (gather) stats."""
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    if psum_axes is not None:
        n_dev = jax.lax.psum(1, psum_axes)
        me = jax.lax.psum(me, psum_axes) / n_dev
        ce = jax.lax.psum(ce, psum_axes) / n_dev
        dropped = jax.lax.psum(dropped, psum_axes) / n_dev
    return {"lb_loss": E * jnp.sum(me * ce), "dropped_frac": dropped}


def _moe_chunk(p, xf, cfg: ArchConfig):
    """One dispatch chunk: xf (n, d) -> (y (n, d), aux)."""
    from repro.models.sharding import current_mesh

    mesh = current_mesh()
    use_a2a = cfg.moe_dispatch == "a2a" and mesh is not None
    if use_a2a:
        n_dev = 1
        for s in mesh.shape.values():
            n_dev *= s
        use_a2a = xf.shape[0] % n_dev == 0   # decode batches < devices: gather
    if use_a2a:
        y, aux = _moe_chunk_a2a(p, xf, cfg)
    else:
        y, aux = _moe_chunk_gather(p, xf, cfg)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], xf)
    return y, aux


def _moe_chunk_gather(p, xf, cfg: ArchConfig):
    """GSPMD gather-based dispatch (baseline). The compiler all-gathers the
    chunk's tokens over the data axes to build the expert buffers — simple
    and correct, but moves every token to every device (§Perf hillclimb A
    replaces this with the a2a path below)."""
    from repro.models.sharding import constrain

    n, d = xf.shape
    dt = cfg.compute_dtype
    C = _capacity(cfg, n)
    gate_vals, keep, slot_e, slot_c, slot_src, probs, gate_idx = \
        _route(p, xf, cfg, C)

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), dt)], axis=0)
    expert_in = xpad[slot_src]                                     # (Ep, C, d)
    # EP anchor: experts over "model", capacity slots over the batch axes —
    # without the second axis every data shard would redundantly compute ALL
    # of each expert's slots (16x wasted FLOPs on a 16x16 mesh).
    expert_in = constrain(expert_in, "model", "batch", None)
    expert_out = _experts_ffn(expert_in, p["experts_wi"], p["experts_wg"],
                              p["experts_wo"], dt)
    y = _combine(expert_out, gate_vals, keep, slot_e, slot_c, n, C, dt)
    return y, _aux_stats(cfg, probs, gate_idx, keep)


def _moe_chunk_a2a(p, xf, cfg: ArchConfig):
    """shard_map all-to-all dispatch (§Perf hillclimb A).

    Tokens stay on their home shard; each device routes its n/devices tokens
    locally, builds an (Ep, C_loc, d) buffer, and ONE all_to_all over the
    model axis delivers each expert's slots to the device holding that
    expert's weights (a second a2a returns the outputs). Wire per device per
    chunk = 2 * Ep*C_loc*d*2B ~= 2 * (K * capacity_factor) * token bytes —
    vs the gather baseline's all-gather of ALL tokens to ALL devices plus a
    model-axis gather of every expert buffer (measured ~10x more).
    """
    from repro.launch.mesh import batch_axes
    from repro.models.sharding import current_mesh
    from jax.sharding import PartitionSpec as P

    mesh = current_mesh()
    n, d = xf.shape
    dt = cfg.compute_dtype
    tok_axes = tuple(batch_axes(mesh)) + ("model",)
    n_dev = 1
    for a in tok_axes:
        n_dev *= mesh.shape[a]
    model_n = mesh.shape["model"]
    Ep = cfg.padded_experts
    assert n % n_dev == 0, (n, n_dev)
    n_loc = n // n_dev
    C_loc = _capacity(cfg, n_loc)

    def local_fn(xf_loc, router, wi, wg, wo):
        gate_vals, keep, slot_e, slot_c, slot_src, probs, gate_idx = \
            _route({"router": router}, xf_loc, cfg, C_loc)
        xpad = jnp.concatenate([xf_loc, jnp.zeros((1, d), dt)], axis=0)
        expert_in = xpad[slot_src]                         # (Ep, C_loc, d)
        # deliver slots to the expert owners: (Ep/m, m*C_loc, d) per device
        expert_in = jax.lax.all_to_all(expert_in, "model", split_axis=0,
                                       concat_axis=1, tiled=True)
        expert_out = _experts_ffn(expert_in, wi, wg, wo, dt)
        # return outputs to the token owners
        expert_out = jax.lax.all_to_all(expert_out, "model", split_axis=1,
                                        concat_axis=0, tiled=True)
        y = _combine(expert_out, gate_vals, keep, slot_e, slot_c,
                     n_loc, C_loc, dt)
        aux = _aux_stats(cfg, probs, gate_idx, keep, psum_axes=tok_axes)
        return y, aux

    mapped = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(tok_axes), P(), P("model"), P("model"), P("model")),
        out_specs=(P(tok_axes), P()))
    return mapped(xf, p["router"], p["experts_wi"], p["experts_wg"],
                  p["experts_wo"])


def kmeans_router_init(key, p_moe, token_embeds, cfg: ArchConfig, *,
                       variant: str = "fused"):
    """Initialize router weights from k-means++ centroids of token embeddings
    (paper integration #2): router logit_e = x . c_e gives balanced early
    routing. token_embeds (N, d) — typically one batch of embedded tokens."""
    from repro.core import kmeanspp
    res = kmeanspp(key, token_embeds.astype(jnp.float32), cfg.n_experts,
                   variant=variant)
    cents = res.centroids / (jnp.linalg.norm(res.centroids, axis=1,
                                             keepdims=True) + 1e-6)
    return {**p_moe, "router": cents.T.astype(jnp.float32)}
