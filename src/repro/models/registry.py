"""Uniform model interface: family -> (init_params, forward, loss_fn,
prefill, decode_step, init_cache)."""
from __future__ import annotations

from types import SimpleNamespace

from repro.configs.common import ArchConfig
from repro.models import encdec, hybrid, rwkv_model, transformer


def get_model(cfg: ArchConfig) -> SimpleNamespace:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
    elif cfg.family == "hybrid":
        mod = hybrid
    elif cfg.family == "ssm":
        mod = rwkv_model
    elif cfg.family == "encdec":
        mod = encdec
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return SimpleNamespace(
        init_params=lambda key: mod.init_params(key, cfg),
        forward=lambda params, batch: mod.forward(params, cfg, batch),
        loss_fn=lambda params, batch: mod.loss_fn(params, cfg, batch),
        prefill=lambda params, batch, **kw: mod.prefill(params, cfg, batch,
                                                        **kw),
        decode_step=lambda params, token, cache, **kw: mod.decode_step(
            params, cfg, token, cache, **kw),
        init_cache=lambda B, S_max: mod.init_cache(cfg, B, S_max),
        module=mod,
        cfg=cfg,
    )
