"""Zamba2-style hybrid: a Mamba2 backbone with ONE shared attention+MLP block
(same weights every invocation) applied every `attn_every` mamba layers, fed
with concat(hidden, first-layer embedding) through a shared down-projection.

Scan structure: scan over groups of `attn_every` mamba layers; the shared
block runs after every group (shared weights live OUTSIDE the scanned stack,
so lax.scan sees a uniform body — no per-step param stacking).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.sharding import constrain


def n_groups(cfg: ArchConfig) -> int:
    """Full groups of attn_every mamba layers + 1 shared-attn invocation;
    n_layers % attn_every trailing mamba layers run after the scan (no attn)."""
    return cfg.n_layers // cfg.attn_every


def n_tail(cfg: ArchConfig) -> int:
    return cfg.n_layers - n_groups(cfg) * cfg.attn_every


def init_params(key, cfg: ArchConfig):
    G, T = n_groups(cfg), n_tail(cfg)
    keys = jax.random.split(key, 6)
    lkeys = jax.random.split(keys[0], G * cfg.attn_every)
    tkeys = jax.random.split(keys[5], max(T, 1))

    def layer_init(k):
        return {"mamba": M.mamba2_init(k, cfg),
                "ln": jnp.ones((cfg.d_model,), jnp.float32)}

    def group_init(gkeys):
        return [layer_init(gkeys[i]) for i in range(cfg.attn_every)]

    stacked = jax.vmap(group_init)(
        lkeys.reshape(G, cfg.attn_every, *lkeys.shape[1:]))
    tail = jax.vmap(layer_init)(tkeys[:T]) if T else None
    shared = {
        "proj_in": L.dense_init(keys[1], (2 * cfg.d_model, cfg.d_model)),
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": L.attn_init(keys[2], cfg),
        "mlp": L.mlp_init(keys[3], cfg.d_model, cfg.d_ff, cfg.n_layers),
    }
    params = {
        "layers": stacked,
        "shared": shared,
        "embed": L.embed_init(keys[4], (cfg.padded_vocab, cfg.d_model)),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if tail is not None:
        params["tail"] = tail
    return params


def _tail_apply(params, cfg: ArchConfig, h, *, states=None):
    """Trailing mamba layers (scan, no shared attention). states: stacked
    decode states (T, ...) or None for full-seq. Returns (h, new_states)."""
    if "tail" not in params:
        return h, states

    if states is None:
        def body(hh, p):
            x = L.rms_norm(hh, p["ln"], eps=cfg.norm_eps)
            y, st = M.mamba2_apply(p["mamba"], x, cfg)
            return hh + y, st
        h, sts = jax.lax.scan(body, h, params["tail"])
        return h, sts

    def body(hh, xs):
        p, st = xs
        x = L.rms_norm(hh, p["ln"], eps=cfg.norm_eps)
        y, st_new = M.mamba2_decode(p["mamba"], x, cfg, st)
        return hh + y, st_new
    h, sts = jax.lax.scan(body, h, (params["tail"], states))
    return h, sts


def _shared_block(p, h, h0, cfg: ArchConfig, cos, sin, *, cache=None, pos=None):
    """Shared attention+MLP. Returns (delta, (k, v) or updated cache slice)."""
    dt = cfg.compute_dtype
    x = jnp.concatenate([h, h0], axis=-1) @ p["proj_in"].astype(dt)
    a_in = L.rms_norm(x, p["ln1"], eps=cfg.norm_eps)
    q, k, v = L.attn_qkv(p["attn"], a_in, cfg, cos, sin)
    if cache is None:
        o = L.blocked_attention(q, k, v, causal=True,
                                block_q=cfg.attn_block_q,
                                block_kv=cfg.attn_block_kv)
        kv = (k, v)
    else:
        k_c = jax.lax.dynamic_update_slice_in_dim(
            cache[0], k.astype(jnp.bfloat16), pos, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(
            cache[1], v.astype(jnp.bfloat16), pos, axis=1)
        o = L.decode_attention(q, k_c, v_c, pos + 1)
        kv = (k_c, v_c)
    o = L.attn_out(p["attn"], o, cfg)
    x = x + o
    m = L.mlp_apply(p["mlp"], L.rms_norm(x, p["ln2"], eps=cfg.norm_eps))
    return x + m, kv


def forward(params, cfg: ArchConfig, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    dt = cfg.compute_dtype
    h = L.embed_lookup(params["embed"], tokens, dt)
    h0 = h
    h = constrain(h, "batch", None, None)
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
    shared = params["shared"]

    def group_body(h, group_params):
        def inner(h, group_params):
            for i in range(cfg.attn_every):
                p = group_params[i]
                x = L.rms_norm(h, p["ln"], eps=cfg.norm_eps)
                y, _ = M.mamba2_apply(p["mamba"], x, cfg)
                h = constrain(h + y, "batch", None, None)
            delta, _ = _shared_block(shared, h, h0, cfg, cos, sin)
            return constrain(h + delta, "batch", None, None)
        if cfg.remat:
            inner = jax.checkpoint(inner)
        return inner(h, group_params), None

    h, _ = jax.lax.scan(group_body, h, params["layers"])
    h, _ = _tail_apply(params, cfg, h)
    h = L.rms_norm(h, params["ln_f"], eps=cfg.norm_eps)
    logits = L.unembed(h, params["embed"], cap=cfg.logit_softcap)
    return constrain(logits, "batch", None, "model")


def loss_fn(params, cfg: ArchConfig, batch):
    return L.cross_entropy(forward(params, cfg, batch), batch["labels"],
                           vocab=cfg.vocab)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, B: int, S_max: int):
    G, T = n_groups(cfg), n_tail(cfg)
    hd = cfg.resolved_head_dim
    kv_shape = (G, B, S_max, cfg.n_kv_heads, hd)
    ssm = M.mamba2_state_init(cfg, B)

    def rep(n):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), ssm)

    cache = {
        "k": jnp.zeros(kv_shape, jnp.bfloat16),
        "v": jnp.zeros(kv_shape, jnp.bfloat16),
        "ssm": rep(G * cfg.attn_every),
        "pos": jnp.zeros((), jnp.int32),
    }
    if T:
        cache["tail_ssm"] = rep(T)
    return cache


def prefill(params, cfg: ArchConfig, batch, *, cache_len: Optional[int] = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    S_max = cache_len or S
    dt = cfg.compute_dtype
    h = L.embed_lookup(params["embed"], tokens, dt)
    h0 = h
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
    shared = params["shared"]
    G, T = n_groups(cfg), n_tail(cfg)

    def group_body(h, group_params):
        ssm_states = []
        for i in range(cfg.attn_every):
            p = group_params[i]
            x = L.rms_norm(h, p["ln"], eps=cfg.norm_eps)
            y, st = M.mamba2_apply(p["mamba"], x, cfg)
            ssm_states.append(st)
            h = h + y
        delta, (k, v) = _shared_block(shared, h, h0, cfg, cos, sin)
        h = h + delta
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ssm_states)
        return h, (stacked, k, v)

    h, (ssm_all, k_all, v_all) = jax.lax.scan(group_body, h, params["layers"])
    h, tail_states = _tail_apply(params, cfg, h)

    def fix_kv(x):
        pad = S_max - S
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))) \
            .astype(jnp.bfloat16)

    cache = {
        "k": fix_kv(k_all), "v": fix_kv(v_all),
        # (G, ae, ...) -> (G*ae, ...): exact states incl. the conv tail
        "ssm": jax.tree.map(
            lambda x: x.reshape((G * cfg.attn_every,) + x.shape[2:]), ssm_all),
        "pos": jnp.asarray(S, jnp.int32),
    }
    if T:
        cache["tail_ssm"] = tail_states
    hl = L.rms_norm(h[:, -1:], params["ln_f"], eps=cfg.norm_eps)
    logits = L.unembed(hl, params["embed"], cap=cfg.logit_softcap)
    return logits[:, 0], cache


def decode_step(params, cfg: ArchConfig, token, cache, **_):
    B = token.shape[0]
    pos = cache["pos"]
    dt = cfg.compute_dtype
    h = L.embed_lookup(params["embed"], token, dt)
    h0 = h
    cos, sin = L.rope_cos_sin(jnp.full((B, 1), pos, jnp.int32),
                              cfg.resolved_head_dim, cfg.rope_theta)
    shared = params["shared"]
    G, T = n_groups(cfg), n_tail(cfg)
    ae = cfg.attn_every

    def fold(x):
        return x.reshape((G, ae) + x.shape[1:])

    ssm_f = jax.tree.map(fold, cache["ssm"])

    def group_body(h, xs):
        group_params, ssm_g, k_g, v_g = xs
        new_states = []
        for i in range(ae):
            p = group_params[i]
            st = jax.tree.map(lambda x: x[i], ssm_g)
            x = L.rms_norm(h, p["ln"], eps=cfg.norm_eps)
            y, st_new = M.mamba2_decode(p["mamba"], x, cfg, st)
            new_states.append(st_new)
            h = h + y
        delta, (k_new, v_new) = _shared_block(shared, h, h0, cfg, cos, sin,
                                              cache=(k_g, v_g), pos=pos)
        h = h + delta
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_states)
        return h, (stacked, k_new, v_new)

    h, (ssm_new, k_new, v_new) = jax.lax.scan(
        group_body, h, (params["layers"], ssm_f, cache["k"], cache["v"]))

    new_cache = {
        "k": k_new, "v": v_new,
        "ssm": jax.tree.map(
            lambda x: x.reshape((G * ae,) + x.shape[2:]), ssm_new),
        "pos": pos + 1,
    }
    if T:
        h, tail_new = _tail_apply(params, cfg, h, states=cache["tail_ssm"])
        new_cache["tail_ssm"] = tail_new

    h = L.rms_norm(h, params["ln_f"], eps=cfg.norm_eps)
    logits = L.unembed(h, params["embed"], cap=cfg.logit_softcap)
    return logits[:, 0], new_cache
