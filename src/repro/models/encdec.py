"""Whisper-style encoder-decoder backbone. The conv/mel frontend is a STUB:
`input_specs()` provides precomputed frame embeddings (B, S_enc, d_model);
the encoder is a bidirectional transformer over them, the decoder a causal
transformer with cross-attention. Decode shapes exercise the DECODER
(self-attn KV cache + precomputed cross-attn KV)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models import layers as L
from repro.models.sharding import constrain


def init_params(key, cfg: ArchConfig):
    keys = jax.random.split(key, 8)
    d = cfg.d_model

    def enc_layer(k):
        ks = jax.random.split(k, 2)
        return {"ln1": jnp.ones((d,), jnp.float32),
                "ln2": jnp.ones((d,), jnp.float32),
                "attn": L.attn_init(ks[0], cfg),
                "mlp": L.mlp_init(ks[1], d, cfg.d_ff, cfg.n_layers,
                                  gated=False)}

    def dec_layer(k):
        ks = jax.random.split(k, 3)
        return {"ln1": jnp.ones((d,), jnp.float32),
                "ln2": jnp.ones((d,), jnp.float32),
                "ln3": jnp.ones((d,), jnp.float32),
                "attn": L.attn_init(ks[0], cfg),
                "xattn": L.attn_init(ks[1], cfg),
                "mlp": L.mlp_init(ks[2], d, cfg.d_ff, cfg.n_layers,
                                  gated=False)}

    enc_keys = jax.random.split(keys[0], cfg.encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    return {
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "enc_pos": L.embed_init(keys[2], (cfg.encoder_seq, d)),
        "dec_pos": L.embed_init(keys[3], (40960, d)),  # covers 32k decode cells
        "embed": L.embed_init(keys[4], (cfg.padded_vocab, d)),
        "ln_enc": jnp.ones((d,), jnp.float32),
        "ln_f": jnp.ones((d,), jnp.float32),
    }


def encode(params, cfg: ArchConfig, feats):
    """feats (B, S_enc, d_model) precomputed frame embeddings (stub frontend)."""
    dt = cfg.compute_dtype
    S = feats.shape[1]
    h = feats.astype(dt) + params["enc_pos"][:S].astype(dt)[None]
    h = constrain(h, "batch", None, None)

    def body(h, p):
        def inner(h, p):
            a_in = L.rms_norm(h, p["ln1"], eps=cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], a_in, cfg, None, None, rope=False)
            o = L.blocked_attention(q, k, v, causal=False,
                                    block_q=cfg.attn_block_q,
                                    block_kv=cfg.attn_block_kv)
            h = h + L.attn_out(p["attn"], o, cfg)
            m = L.mlp_apply(p["mlp"], L.rms_norm(h, p["ln2"],
                                                 eps=cfg.norm_eps), act="gelu")
            return constrain(h + m, "batch", None, None)
        if cfg.remat:
            inner = jax.checkpoint(inner)
        return inner(h, p), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return L.rms_norm(h, params["ln_enc"], eps=cfg.norm_eps)


def _decoder(params, cfg: ArchConfig, tokens, enc_out, *, collect_kv=False):
    dt = cfg.compute_dtype
    B, S = tokens.shape
    h = L.embed_lookup(params["embed"], tokens, dt) \
        + params["dec_pos"][:S].astype(dt)[None]
    h = constrain(h, "batch", None, None)

    def body(h, p):
        def inner(h, p):
            a_in = L.rms_norm(h, p["ln1"], eps=cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], a_in, cfg, None, None, rope=False)
            o = L.blocked_attention(q, k, v, causal=True,
                                    block_q=cfg.attn_block_q,
                                    block_kv=cfg.attn_block_kv)
            h = h + L.attn_out(p["attn"], o, cfg)
            x_in = L.rms_norm(h, p["ln2"], eps=cfg.norm_eps)
            qx = (x_in @ p["xattn"]["wq"].astype(dt)).reshape(
                B, S, cfg.n_heads, cfg.resolved_head_dim)
            kx, vx = _enc_kv(p, enc_out, cfg)
            ox = L.blocked_attention(qx, kx, vx, causal=False,
                                     block_q=cfg.attn_block_q,
                                     block_kv=cfg.attn_block_kv)
            h = h + L.attn_out(p["xattn"], ox, cfg)
            m = L.mlp_apply(p["mlp"], L.rms_norm(h, p["ln3"],
                                                 eps=cfg.norm_eps), act="gelu")
            return h + m
        if cfg.remat:
            inner = jax.checkpoint(inner)
        return inner(h, p), None

    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    return L.rms_norm(h, params["ln_f"], eps=cfg.norm_eps)


def _enc_kv(p, enc_out, cfg: ArchConfig):
    """Cross-attention K/V from encoder output (no rope)."""
    B, Se, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    dt = cfg.compute_dtype
    k = (enc_out @ p["xattn"]["wk"].astype(dt)).reshape(B, Se,
                                                        cfg.n_kv_heads, hd)
    v = (enc_out @ p["xattn"]["wv"].astype(dt)).reshape(B, Se,
                                                        cfg.n_kv_heads, hd)
    return k, v


def forward(params, cfg: ArchConfig, batch):
    enc_out = encode(params, cfg, batch["encoder_feats"])
    h = _decoder(params, cfg, batch["tokens"], enc_out)
    logits = L.unembed(h, params["embed"], cap=cfg.logit_softcap)
    return constrain(logits, "batch", None, "model")


def loss_fn(params, cfg: ArchConfig, batch):
    return L.cross_entropy(forward(params, cfg, batch), batch["labels"],
                           vocab=cfg.vocab)


# ---------------------------------------------------------------------------
# serving (decoder KV cache + cached cross KV)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, B: int, S_max: int):
    hd = cfg.resolved_head_dim
    Lc = cfg.n_layers
    return {
        "k": jnp.zeros((Lc, B, S_max, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((Lc, B, S_max, cfg.n_kv_heads, hd), jnp.bfloat16),
        "xk": jnp.zeros((Lc, B, cfg.encoder_seq, cfg.n_kv_heads, hd),
                        jnp.bfloat16),
        "xv": jnp.zeros((Lc, B, cfg.encoder_seq, cfg.n_kv_heads, hd),
                        jnp.bfloat16),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ArchConfig, batch, *,
            cache_len: Optional[int] = None):
    """Encode audio features + run the prompt tokens through the decoder."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    S_max = cache_len or S
    dt = cfg.compute_dtype
    enc_out = encode(params, cfg, batch["encoder_feats"])
    h = L.embed_lookup(params["embed"], tokens, dt) \
        + params["dec_pos"][:S].astype(dt)[None]

    def body(h, p):
        a_in = L.rms_norm(h, p["ln1"], eps=cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], a_in, cfg, None, None, rope=False)
        o = L.blocked_attention(q, k, v, causal=True,
                                block_q=cfg.attn_block_q,
                                block_kv=cfg.attn_block_kv)
        h = h + L.attn_out(p["attn"], o, cfg)
        x_in = L.rms_norm(h, p["ln2"], eps=cfg.norm_eps)
        qx = (x_in @ p["xattn"]["wq"].astype(dt)).reshape(
            B, S, cfg.n_heads, cfg.resolved_head_dim)
        kx, vx = _enc_kv(p, enc_out, cfg)
        ox = L.blocked_attention(qx, kx, vx, causal=False,
                                 block_q=cfg.attn_block_q,
                                 block_kv=cfg.attn_block_kv)
        h = h + L.attn_out(p["xattn"], ox, cfg)
        m = L.mlp_apply(p["mlp"], L.rms_norm(h, p["ln3"], eps=cfg.norm_eps),
                        act="gelu")
        h = h + m
        return h, (k, v, kx, vx)

    h, (k_all, v_all, xk_all, xv_all) = jax.lax.scan(body, h,
                                                     params["dec_layers"])

    def fix(x, s_to):
        pad = s_to - x.shape[2]
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))) \
            .astype(jnp.bfloat16)

    cache = {"k": fix(k_all, S_max), "v": fix(v_all, S_max),
             "xk": xk_all.astype(jnp.bfloat16),
             "xv": xv_all.astype(jnp.bfloat16),
             "pos": jnp.asarray(S, jnp.int32)}
    hl = L.rms_norm(h[:, -1:], params["ln_f"], eps=cfg.norm_eps)
    logits = L.unembed(hl, params["embed"], cap=cfg.logit_softcap)
    return logits[:, 0], cache


def decode_step(params, cfg: ArchConfig, token, cache, **_):
    B = token.shape[0]
    pos = cache["pos"]
    dt = cfg.compute_dtype
    h = L.embed_lookup(params["embed"], token, dt) \
        + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0) \
        .astype(dt)[None]

    def body(h, xs):
        p, k_g, v_g, xk_g, xv_g = xs
        a_in = L.rms_norm(h, p["ln1"], eps=cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], a_in, cfg, None, None, rope=False)
        k_c = jax.lax.dynamic_update_slice_in_dim(
            k_g, k.astype(jnp.bfloat16), pos, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(
            v_g, v.astype(jnp.bfloat16), pos, axis=1)
        o = L.decode_attention(q, k_c, v_c, pos + 1)
        h = h + L.attn_out(p["attn"], o, cfg)
        x_in = L.rms_norm(h, p["ln2"], eps=cfg.norm_eps)
        qx = (x_in @ p["xattn"]["wq"].astype(dt)).reshape(
            B, 1, cfg.n_heads, cfg.resolved_head_dim)
        ox = L.decode_attention(qx, xk_g, xv_g, xk_g.shape[1])
        h = h + L.attn_out(p["xattn"], ox, cfg)
        m = L.mlp_apply(p["mlp"], L.rms_norm(h, p["ln3"], eps=cfg.norm_eps),
                        act="gelu")
        h = h + m
        return h, (k_c, v_c)

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = L.rms_norm(h, params["ln_f"], eps=cfg.norm_eps)
    logits = L.unembed(h, params["embed"], cap=cfg.logit_softcap)
    new_cache = {**cache, "k": k_new, "v": v_new, "pos": pos + 1}
    return logits[:, 0], new_cache
