"""repro.models — the 10 assigned architectures as pure-JAX pytree models.

registry.get_model(cfg) returns the uniform interface (init_params, forward,
loss_fn, prefill, decode_step, init_cache) for any family: dense / moe / vlm
(transformer.py), hybrid Mamba2+shared-attn (hybrid.py), attention-free
RWKV6 (rwkv_model.py), enc-dec whisper (encdec.py). partition.py holds the
TP/EP PartitionSpec rules; sharding.py the mesh-context constraint helpers.
"""
from repro.models.registry import get_model

__all__ = ["get_model"]
