"""Shared model building blocks (pure JAX, functional, pytree params).

Design notes:
  * Everything is written so a stack of layers can be `lax.scan`ned (HLO size
    O(1) in depth — required for tractable 512-device dry-run compiles).
  * Attention is a blocked, online-softmax ("flash-style") scan over KV blocks:
    O(S * block) memory, works at 32k prefill; wrapped in jax.checkpoint by the
    layer stacks so the backward recomputes instead of materializing scores.
  * Params are stored fp32 (optimizer precision), compute is cfg.dtype (bf16).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std)


def embed_init(key, shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, scale, *, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale) if plus_one else scale
    return (x * s).astype(dt)


def layer_norm(x, scale, bias, *, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale + bias).astype(dt)


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions, head_dim: int, theta: float, sections):
    """qwen2-vl M-RoPE. positions (B, 3, S); sections sum to head_dim//2.
    Frequency slot i takes its position from component t/h/w per `sections`."""
    inv = rope_freqs(head_dim, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (B, 3, S, hd/2)
    parts, start = [], 0
    for comp, sec in enumerate(sections):
        parts.append(ang[:, comp, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                   # (B, S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, S, H, hd); cos/sin (B, S, hd/2) or (S, hd/2). Half-rotation."""
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    """q (B, bq, KH, G, hd) x k (B, bkv, KH, hd) -> (B, KH, G, bq, bkv) fp32."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      cap: float = 0.0, block_q: int = 512,
                      block_kv: int = 1024, q_offset: int = 0):
    """Online-softmax attention, scanned over KV blocks.

    q (B, Sq, H, hd); k/v (B, Skv, KH, hd) with H = KH * G. Memory per step is
    O(B * Sq * H/KH * block_kv) — never the full (Sq, Skv) score matrix.
    `q_offset` shifts query positions (decode/chunked prefill).
    """
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = hd ** -0.5
    bkv = min(block_kv, Skv)
    pad_kv = (-Skv) % bkv
    nkv = (Skv + pad_kv) // bkv

    qh = q.reshape(B, Sq, KH, G, hd).astype(jnp.bfloat16)
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0))).astype(jnp.bfloat16)
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0))).astype(jnp.bfloat16)
    kb = kp.reshape(B, nkv, bkv, KH, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nkv, bkv, KH, hd).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, inp):
        m, l, acc = carry
        j, k_j, v_j = inp
        s = _gqa_scores(qh, k_j, scale)            # (B, KH, G, Sq, bkv)
        s = softcap(s, cap)
        kv_pos = j * bkv + jnp.arange(bkv)
        mask = kv_pos[None, :] < Skv               # padded tail
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf) against NaN exp
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(m), corr, 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(jnp.bfloat16), v_j,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nkv), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     cap: float = 0.0):
    """Single-position decode: q (B, 1, H, hd) vs cache (B, S, KH, hd).
    `cache_len` = number of valid positions (the new token's kv already
    written at cache_len - 1)."""
    B, _, H, hd = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = hd ** -0.5
    qh = q.reshape(B, KH, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap)
    pos = jnp.arange(S)
    mask = pos[None] < cache_len
    if window > 0:
        mask = mask & (pos[None] > cache_len - 1 - window)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (params + apply)
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ArchConfig, *, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd)),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd)),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd)),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d),
                         scale=1.0 / (2 * max(cfg.n_layers, 1)) ** 0.5),
    }


def attn_qkv(p, x, cfg: ArchConfig, cos, sin, *, rope: bool = True):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = cfg.compute_dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, cfg.n_kv_heads, hd)
    if rope:
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    return q, k, v


def attn_out(p, o, cfg: ArchConfig):
    B, S = o.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"].astype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int, n_layers: int, *, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (d, f)),
         "wo": dense_init(ks[2], (f, d), scale=1.0 / (2 * n_layers) ** 0.5)}
    if gated:
        p["wg"] = dense_init(ks[1], (d, f))
    return p


def mlp_apply(p, x, *, act: str = "silu"):
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if "wg" in p:
        g = x @ p["wg"].astype(dt)
        if act == "gelu":
            h = jax.nn.gelu(g.astype(jnp.float32)).astype(dt) * h
        else:
            h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_lookup(table, tokens, dtype):
    return jnp.take(table, tokens, axis=0).astype(dtype)


def unembed(x, table, *, cap: float = 0.0):
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return softcap(logits, cap)


def cross_entropy(logits, labels, *, vocab: int):
    """Mean next-token CE; labels < 0 or >= vocab are masked (vocab padding)."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) & (labels < vocab)
    safe = jnp.clip(labels, 0, logits.shape[-1] - 1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
