"""Mamba2 (SSD) layer — chunked state-space dual algorithm, TPU/MXU-friendly.

The SSD recurrence per head (A scalar-identity per head, the Mamba2 choice):

    S_t = a_t * S_{t-1} + dt_t * B_t (x) x_t        S in R^{d_state x head_dim}
    y_t = C_t . S_t + D * x_t

Chunked evaluation (chunk = cfg.ssm_chunk): intra-chunk term is a masked
(c x c) matmul per head (MXU), inter-chunk term is a scan over chunk states —
O(S*c) memory instead of O(S^2), O(1)/token decode via the recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models.layers import dense_init, rms_norm


def mamba2_init(key, cfg: ArchConfig):
    d, din, ds, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    cw = cfg.conv_width
    ks = jax.random.split(key, 10)
    return {
        "wz": dense_init(ks[0], (d, din)),
        "wx": dense_init(ks[1], (d, din)),
        "wB": dense_init(ks[2], (d, ds)),
        "wC": dense_init(ks[3], (d, ds)),
        "wdt": dense_init(ks[4], (d, H)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "conv_x": dense_init(ks[5], (cw, din), scale=cw ** 0.5),
        "conv_B": dense_init(ks[6], (cw, ds), scale=cw ** 0.5),
        "conv_C": dense_init(ks[7], (cw, ds), scale=cw ** 0.5),
        "norm": jnp.ones((din,), jnp.float32),
        "wo": dense_init(ks[8], (din, d),
                         scale=1.0 / (2 * max(cfg.n_layers, 1)) ** 0.5),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x (B, S, C), w (cw, C). state (B, cw-1, C) for
    decode continuity. Returns (y, new_state)."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(cw))
    return y, xp[:, -(cw - 1):]


def _proj_conv(p, x, cfg: ArchConfig, conv_state=None):
    """Shared projection + conv for both chunked and decode paths."""
    dt_c = cfg.compute_dtype
    z = x @ p["wz"].astype(dt_c)
    xs = x @ p["wx"].astype(dt_c)
    Bm = x @ p["wB"].astype(dt_c)
    Cm = x @ p["wC"].astype(dt_c)
    dt = x @ p["wdt"].astype(dt_c)
    # Three separate depthwise convs (not one fused concat): identical math,
    # but xs is TP-sharded over d_inner while B/C are replicated (d_state is
    # tiny) — a concat would force GSPMD to materialize xs unsharded.
    if conv_state is None:
        st_x = st_B = st_C = None
    else:
        st_x, st_B, st_C = conv_state
    xs, new_x = _causal_conv(xs, p["conv_x"], st_x)
    Bm, new_B = _causal_conv(Bm, p["conv_B"], st_B)
    Cm, new_C = _causal_conv(Cm, p["conv_C"], st_C)
    new_conv = (new_x, new_B, new_C)
    act = lambda t: jax.nn.silu(t.astype(jnp.float32)).astype(dt_c)
    xs, Bm, Cm = act(xs), act(Bm), act(Cm)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return z, xs, Bm, Cm, dt, new_conv


def mamba2_apply(p, x, cfg: ArchConfig, *, init_state=None):
    """Full-sequence (train/prefill) chunked SSD. x (B, S, d_model).
    Returns (y (B, S, d_model), state dict {"ssm", "conv"}) — the state is
    exact (incl. the depthwise-conv tail), so prefill->decode is seamless."""
    B_, S, _ = x.shape
    H, hd, ds = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    c = min(cfg.ssm_chunk, S)
    assert S % c == 0, f"seq {S} % chunk {c} != 0"
    nc = S // c
    dt_c = cfg.compute_dtype

    z, xs, Bm, Cm, dt, conv_tail = _proj_conv(p, x, cfg)
    xh = xs.reshape(B_, nc, c, H, hd)
    Bc = Bm.reshape(B_, nc, c, ds).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, c, ds).astype(jnp.float32)
    dtc = dt.reshape(B_, nc, c, H)                       # fp32
    A = -jnp.exp(p["A_log"])                             # (H,) negative
    la = dtc * A                                         # log decay <= 0
    cum = jnp.cumsum(la, axis=2)                         # (B, nc, c, H)

    if init_state is None:
        init_state = jnp.zeros((B_, H, ds, hd), jnp.float32)

    def chunk_step(S_in, inp):
        xj, Bj, Cj, laj, cumj, dtj = inp                 # per-chunk slices
        # intra-chunk: scores[t, j] = (C_t . B_j) * exp(cum_t - cum_j) * dt_j
        G = jnp.einsum("bid,bjd->bij", Cj, Bj,
                       preferred_element_type=jnp.float32)       # (B, c, c)
        # mask BEFORE exp: upper-triangle exponents are positive (overflow to
        # inf, which poisons the backward pass as inf*0 -> NaN); exp(-inf)=0
        # with a zero gradient is safe.
        mask = jnp.tril(jnp.ones((c, c), bool))
        ediff = cumj[:, :, None, :] - cumj[:, None, :, :]          # (B,c,c,H)
        decay = jnp.exp(jnp.where(mask[None, :, :, None], ediff, -jnp.inf))
        scores = G[..., None] * decay * dtj[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores,
                             xj.astype(jnp.float32))
        # inter-chunk: y_t += C_t . (exp(cum_t) * S_in)
        Cdec = Cj[:, :, None, :] * jnp.exp(cumj)[:, :, :, None]  # (B,c,H,ds)
        y_inter = jnp.einsum("bihd,bhdp->bihp", Cdec, S_in)
        # state update: S_out = exp(cum_last) * S_in + sum_j exp(cum_last-cum_j) dt_j B_j (x) x_j
        seg = jnp.exp(cumj[:, -1:, :] - cumj)                     # (B, c, H)
        Bw = Bj[:, :, None, :] * (seg * dtj)[..., None]           # (B,c,H,ds)
        S_new = jnp.einsum("bjhd,bjhp->bhdp", Bw, xj.astype(jnp.float32))
        S_out = jnp.exp(cumj[:, -1])[:, :, None, None] * S_in + S_new
        return S_out, (y_intra + y_inter)

    xs_t = xh.transpose(1, 0, 2, 3, 4)
    inp = (xs_t, Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3),
           la.reshape(B_, nc, c, H).transpose(1, 0, 2, 3),
           cum.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3))
    final_state, ys = jax.lax.scan(chunk_step, init_state, inp)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S, H, hd)
    y = y + xh.reshape(B_, S, H, hd).astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, S, -1).astype(dt_c)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_c)
    y = rms_norm(y, p["norm"], eps=cfg.norm_eps)
    return y @ p["wo"].astype(dt_c), {"ssm": final_state, "conv": conv_tail}


def mamba2_decode(p, x, cfg: ArchConfig, state):
    """One-token step. x (B, 1, d). state = dict(ssm (B,H,ds,hd), conv)."""
    H, hd, ds = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dt_c = cfg.compute_dtype
    z, xs, Bm, Cm, dt, new_conv = _proj_conv(p, x, cfg, state["conv"])
    B_ = x.shape[0]
    xh = xs.reshape(B_, H, hd).astype(jnp.float32)
    Bv = Bm.reshape(B_, ds).astype(jnp.float32)
    Cv = Cm.reshape(B_, ds).astype(jnp.float32)
    dtv = dt.reshape(B_, H)
    a = jnp.exp(dtv * -jnp.exp(p["A_log"]))              # (B, H)
    S = state["ssm"]
    S = a[:, :, None, None] * S + jnp.einsum(
        "bd,bhp->bhdp", Bv, xh * dtv[..., None])
    y = jnp.einsum("bd,bhdp->bhp", Cv, S) + xh * p["D"][None, :, None]
    y = y.reshape(B_, 1, -1).astype(dt_c)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_c)
    y = rms_norm(y, p["norm"], eps=cfg.norm_eps)
    return y @ p["wo"].astype(dt_c), {"ssm": S, "conv": new_conv}


def mamba2_state_init(cfg: ArchConfig, batch: int):
    H, hd, ds = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    cw = cfg.conv_width - 1
    dt = cfg.compute_dtype
    return {"ssm": jnp.zeros((batch, H, ds, hd), jnp.float32),
            "conv": (jnp.zeros((batch, cw, cfg.d_inner), dt),
                     jnp.zeros((batch, cw, ds), dt),
                     jnp.zeros((batch, cw, ds), dt))}
