"""Per-parameter PartitionSpec rules (Megatron-style TP over the "model" axis).

Rules are keyed by the leaf's *name* (last pytree path component) and apply to
the TRAILING dims of the tensor; any leading dims (the lax.scan layer-stacking
dim G, or the expert dim handled explicitly) are unsharded. This makes one
rule table cover every family: dense / moe / hybrid / ssm / encdec / vlm.

Column-parallel (output-dim sharded, no collective on entry):
    wq wk wv wi wg           attention QKV + MLP up/gate
    wz wx wdt                mamba2 in-projections (d_inner / heads sharded)
    wr wk wv wg(rwkv) ck cr  rwkv6 time/channel-mix in-projections
    wB_lora                  rwkv6 decay LoRA up
Row-parallel (input-dim sharded, one psum on exit):
    wo cv                    attention/MLP/mamba/rwkv out-projections
Vocab-sharded:  embed unembed    (V, d) -> ("model", None)
Expert-sharded: experts_*        (E, d, f) -> ("model", None, None)
Head/channel vectors (sharded like the dim they scale):
    A_log D dt_bias (H,) ; norm ln_x w0 u (din/d/H,hd)
Everything else (norms, router, biases, mu): replicated.

Optimizer moments reuse the same specs (same tree structure).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> spec over the TRAILING dims
_COL = {"wq", "wk", "wv", "wi", "wg", "wz", "wx", "wdt",
        "wr", "ck", "cr", "wB_lora", "proj_in"}
_ROW = {"wo", "cv"}
_VOCAB = {"embed", "unembed"}
_EXPERT = {"experts_wi", "experts_wg", "experts_wo"}
_SHARDED_VEC = {"A_log", "D", "dt_bias", "norm", "ln_x", "w0"}
_SHARDED_2D = {"u"}          # (H, hd) -> ("model", None)
_REPLICATED = {"router", "mu", "cmu", "ln1", "ln2", "ln3", "ln1_post",
               "ln2_post", "ln_f", "ln_in", "ln_enc", "wA_lora",
               "wB", "wC", "conv_x", "conv_B", "conv_C",
               "enc_pos", "dec_pos"}
# conv_x (cw, din) is sharded on its channel dim:
_CONV_SHARDED = {"conv_x"}


def spec_for(path, leaf) -> P:
    """PartitionSpec for one param leaf given its pytree path."""
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = str(entry.key)
            break
        if isinstance(entry, jax.tree_util.GetAttrKey):
            name = entry.name
            break
    ndim = leaf.ndim
    lead = ()

    def trail(*spec):
        assert len(spec) <= ndim, (name, spec, leaf.shape)
        return P(*([None] * (ndim - len(spec)) + list(spec)))

    if name in _EXPERT:
        # (E, d, f): EP over model axis on the expert dim
        return P(*(["model"] + [None] * (ndim - 1))[-ndim:]) if ndim >= 1 else P()
    if name in _CONV_SHARDED:
        return trail(None, "model")
    if name in _VOCAB:
        return trail("model", None)
    if name in _COL:
        return trail(None, "model")
    if name in _ROW:
        return trail("model", None)
    if name in _SHARDED_2D:
        return trail("model", None)
    if name in _SHARDED_VEC:
        return trail("model")
    return P()  # replicated (norm scales, router, biases, small tables)


def _expert_aware_spec(path, leaf) -> P:
    """Expert tensors keep their stacked-layer leading dim unsharded but the
    expert dim (dim -3 for (G, E, d, f) or dim 0 for (E, d, f)) on "model"."""
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = str(entry.key)
            break
    if name in _EXPERT:
        # trailing three dims are (E, d|f, f|d)
        ndim = leaf.ndim
        spec = [None] * ndim
        spec[ndim - 3] = "model"
        return P(*spec)
    return spec_for(path, leaf)


def param_specs(params) -> Any:
    """Pytree of PartitionSpecs matching `params`."""
    return jax.tree_util.tree_map_with_path(_expert_aware_spec, params)


def param_shardings(mesh: Mesh, params) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(params))


def shardings_like(mesh: Mesh, tree, specs) -> Any:
    del tree
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
