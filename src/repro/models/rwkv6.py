"""RWKV6 "Finch" — attention-free token mixer with data-dependent decay.

Per head (hd = key/value dim per head):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T              S in R^{hd x hd}
    y_t = r_t . (S_{t-1}) + (r_t (.) u . k_t) v_t    (u = per-head bonus)

Chunked evaluation: a scan over chunks carries the (B, H, hd, hd) state;
within a chunk the pairwise decay  exp(ecum_t - cum_j)  (elementwise over the
key dim) turns the recurrence into masked matmuls. The exponent is <= 0 for
every in-chunk pair (j < t), so we materialize the (c, c, hd) decay tensor
directly rather than using the exp(a)*exp(-b) factorization, which overflows
under strong decay. Memory per chunk step: B*H*c^2*hd fp32 — bounded by the
chunk size (default 64 for RWKV6). O(1)/token decode via the recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models.layers import dense_init

_LORA = 64  # low-rank size for the data-dependent decay

RWKV_CHUNK = 64


def rwkv6_init(key, cfg: ArchConfig):
    d, H, hd = cfg.d_model, cfg.n_rwkv_heads, cfg.rwkv_head_dim
    f = cfg.d_ff
    ks = jax.random.split(key, 12)
    out_scale = 1.0 / (2 * max(cfg.n_layers, 1)) ** 0.5
    return {
        # time mix
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),  # r,k,v,w,g mixes
        "wr": dense_init(ks[1], (d, d)),
        "wk": dense_init(ks[2], (d, d)),
        "wv": dense_init(ks[3], (d, d)),
        "wg": dense_init(ks[4], (d, d)),
        "w0": jnp.full((d,), -2.0, jnp.float32),               # base decay
        "wA_lora": dense_init(ks[5], (d, _LORA)),
        "wB_lora": dense_init(ks[6], (_LORA, d)),
        "u": dense_init(ks[7], (H, hd)),                       # bonus
        "ln_x": jnp.ones((d,), jnp.float32),                   # head groupnorm
        "wo": dense_init(ks[8], (d, d), scale=out_scale),
        # channel mix
        "cmu": jax.random.uniform(ks[9], (2, d), jnp.float32),  # k, r mixes
        "ck": dense_init(ks[10], (d, f)),
        "cv": dense_init(ks[11], (f, d), scale=out_scale),
        "cr": dense_init(jax.random.fold_in(key, 99), (d, d)),
    }


def _shift(x, prev):
    """Token shift: concat(prev_token, x[:-1]). prev (B, 1, d)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _group_norm(y, scale, H, eps=1e-5):
    """Per-head layer norm over the value dim (RWKV's GroupNorm(H))."""
    B, S, d = y.shape
    yh = y.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B, S, d) * scale).astype(y.dtype)


def _rkvwg(p, x, x_prev, cfg: ArchConfig):
    dt = cfg.compute_dtype
    xs = _shift(x, x_prev)
    mixed = [x + (xs - x) * p["mu"][i].astype(dt) for i in range(5)]
    r = mixed[0] @ p["wr"].astype(dt)
    k = mixed[1] @ p["wk"].astype(dt)
    v = mixed[2] @ p["wv"].astype(dt)
    g = mixed[4] @ p["wg"].astype(dt)
    # data-dependent decay (LoRA): log w in (-inf, 0)
    ww = p["w0"] + jnp.tanh(mixed[3].astype(jnp.float32) @ p["wA_lora"]) @ p["wB_lora"]
    log_w = -jnp.exp(ww)                                   # (B, S, d) < 0
    return r, k, v, g, log_w


def rwkv6_time_mix(p, x, cfg: ArchConfig, *, x_prev=None, state=None):
    """Full-sequence chunked WKV. x (B, S, d). Returns (y, (last_x, state))."""
    B, S, d = x.shape
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    c = min(RWKV_CHUNK, S)
    assert S % c == 0
    nc = S // c
    dt = cfg.compute_dtype

    if x_prev is None:
        x_prev = jnp.zeros((B, 1, d), dt)
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    r, k, v, g, log_w = _rkvwg(p, x, x_prev, cfg)
    rh = r.reshape(B, nc, c, H, hd).astype(jnp.float32)
    kh = k.reshape(B, nc, c, H, hd).astype(jnp.float32)
    vh = v.reshape(B, nc, c, H, hd).astype(jnp.float32)
    cum = jnp.cumsum(log_w.reshape(B, nc, c, H, hd), axis=2)   # inclusive, <= 0
    u = p["u"]                                                  # (H, hd)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)               # strictly lower

    def chunk_step(S_in, inp):
        rj, kj, vj, cumj = inp                                  # (B, c, H, hd)
        ecum = jnp.pad(cumj[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0)))
        # inter-chunk: y_t += (r_t (.) exp(ecum_t)) . S_in      (exp <= 1)
        y_inter = jnp.einsum("bihd,bhdv->bihv", rj * jnp.exp(ecum), S_in)
        # intra-chunk, exact pairwise decay (exponent <= 0 for j < t):
        pair = jnp.exp(jnp.where(mask[None, :, :, None, None],
                                 ecum[:, :, None] - cumj[:, None, :], -jnp.inf))
        scores = jnp.einsum("bihd,bjhd,bijhd->bhij", rj, kj, pair)
        y_intra = jnp.einsum("bhij,bjhv->bihv", scores, vj)
        # bonus u on the diagonal: y_t += (r_t (.) u . k_t) v_t
        diag = jnp.einsum("bihd,bihd->bih", rj * u[None, None], kj)
        y_intra = y_intra + diag[..., None] * vj
        # state: S_out = exp(cum_last) (.) S_in + sum_j (k_j (.) exp(cum_last - cum_j)) v_j^T
        kdec = kj * jnp.exp(cumj[:, -1:] - cumj)                # <= 1
        S_out = jnp.exp(cumj[:, -1])[..., None] * S_in + \
            jnp.einsum("bjhd,bjhv->bhdv", kdec, vj)
        return S_out, y_inter + y_intra

    inp = (rh.transpose(1, 0, 2, 3, 4), kh.transpose(1, 0, 2, 3, 4),
           vh.transpose(1, 0, 2, 3, 4), cum.transpose(1, 0, 2, 3, 4))
    S_fin, ys = jax.lax.scan(chunk_step, state, inp)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, d)
    y = _group_norm(y.astype(dt), p["ln_x"], H)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(dt)
    return y @ p["wo"].astype(dt), (x[:, -1:], S_fin)


def rwkv6_time_mix_decode(p, x, cfg: ArchConfig, x_prev, state):
    """One-token step. x (B, 1, d)."""
    B, _, d = x.shape
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    dt = cfg.compute_dtype
    r, k, v, g, log_w = _rkvwg(p, x, x_prev, cfg)
    rh = r.reshape(B, H, hd).astype(jnp.float32)
    kh = k.reshape(B, H, hd).astype(jnp.float32)
    vh = v.reshape(B, H, hd).astype(jnp.float32)
    w = jnp.exp(log_w.reshape(B, H, hd))
    u = p["u"]
    y = jnp.einsum("bhd,bhdv->bhv", rh, state) + \
        jnp.einsum("bhd,bhd->bh", rh * u[None], kh)[..., None] * vh
    state = w[..., None] * state + jnp.einsum("bhd,bhv->bhdv", kh, vh)
    y = y.reshape(B, 1, d).astype(dt)
    y = _group_norm(y, p["ln_x"], H)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(dt)
    return y @ p["wo"].astype(dt), (x, state)


def rwkv6_channel_mix(p, x, cfg: ArchConfig, *, x_prev=None):
    """RWKV channel mix (the FFN). Returns (y, last_x)."""
    B, S, d = x.shape
    dt = cfg.compute_dtype
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, d), dt)
    xs = _shift(x, x_prev)
    xk = x + (xs - x) * p["cmu"][0].astype(dt)
    xr = x + (xs - x) * p["cmu"][1].astype(dt)
    kk = jnp.square(jax.nn.relu((xk @ p["ck"].astype(dt)).astype(jnp.float32)))
    rr = jax.nn.sigmoid((xr @ p["cr"].astype(dt)).astype(jnp.float32))
    y = rr * (kk.astype(dt) @ p["cv"].astype(dt)).astype(jnp.float32)
    return y.astype(dt), x[:, -1:]


def rwkv6_state_init(cfg: ArchConfig, batch: int):
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    dt = cfg.compute_dtype
    return {
        "tm_x": jnp.zeros((batch, 1, cfg.d_model), dt),
        "cm_x": jnp.zeros((batch, 1, cfg.d_model), dt),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }
