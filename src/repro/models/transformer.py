"""Decoder-only LM stack: dense (llama-like), gemma2 (alt local/global,
softcaps, sandwich norms), MoE (phi3.5 / qwen2-moe), VLM (qwen2-vl M-RoPE).

Layer stacks are lax.scan'd over a repeating pattern of layer kinds (dense
archs: pattern length 1; gemma2: [local, global]) with stacked params —
HLO size is O(pattern), not O(L), which keeps 512-device dry-run compiles
tractable. Each pattern-group body is jax.checkpoint'ed (remat).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# layer pattern
# ---------------------------------------------------------------------------

def layer_pattern(cfg: ArchConfig):
    """List of per-layer attention windows; scan iterates groups of this size."""
    if cfg.alt_local_global:
        return [cfg.sliding_window, 0]       # gemma2: even local, odd global
    return [cfg.sliding_window]


def n_groups(cfg: ArchConfig) -> int:
    p = len(layer_pattern(cfg))
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return cfg.n_layers // p


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": L.attn_init(ks[0], cfg),
    }
    if cfg.post_norms:
        p["ln1_post"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ln2_post"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.family == "moe":
        p["moe"] = MOE.moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.n_layers)
    return p


def init_params(key, cfg: ArchConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    pat = len(layer_pattern(cfg))
    G = n_groups(cfg)

    def group_init(gkey):
        gks = jax.random.split(gkey, pat)
        return [_layer_init(gks[i], cfg) for i in range(pat)]

    stacked = jax.vmap(group_init)(keys[:G])
    params = {
        "layers": stacked,                       # list of pat dicts, (G, ...)
        "embed": L.embed_init(keys[-1], (cfg.padded_vocab, cfg.d_model)),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.embed_init(keys[-2],
                                         (cfg.padded_vocab, cfg.d_model))
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _rope_for(cfg: ArchConfig, positions):
    hd = cfg.resolved_head_dim
    if cfg.mrope_sections:
        return L.mrope_cos_sin(positions, hd, cfg.rope_theta,
                               cfg.mrope_sections)
    return L.rope_cos_sin(positions, hd, cfg.rope_theta)


def _res_constrain(h, cfg: ArchConfig):
    """Residual-stream sharding between blocks. Baseline: replicated over
    "model". With cfg.seq_shard (§Perf hillclimb B, Korthikanti-style SP):
    the SEQUENCE dim is sharded over "model" — norm/elementwise work and the
    layer-scan carry stacks shrink by the TP degree; GSPMD replaces the
    per-block psum with reduce-scatter + all-gather pairs of equal volume."""
    if cfg.seq_shard and h.shape[1] > 1:
        return constrain(h, "batch", "model", None)
    return constrain(h, "batch", None, None)


def _attn_block(p, h, cfg: ArchConfig, cos, sin, window: int, *,
                q_offset: int = 0):
    a_in = L.rms_norm(h, p["ln1"], eps=cfg.norm_eps)
    q, k, v = L.attn_qkv(p["attn"], a_in, cfg, cos, sin)
    q = constrain(q, "batch", None, "model", None)
    if cfg.attn_stub:
        # measurement-only stand-in (ArchConfig.attn_stub): causal cumsum of
        # v — linear cost, zero score materialization. Used ONLY to attribute
        # attention HBM traffic for §Perf B; never a real model.
        G = cfg.n_heads // max(cfg.n_kv_heads, 1)
        o = jnp.cumsum(v.astype(jnp.float32), axis=1).astype(v.dtype)
        o = jnp.repeat(o, G, axis=2)
    else:
        o = L.blocked_attention(q, k, v, causal=True, window=window,
                                cap=cfg.attn_softcap,
                                block_q=cfg.attn_block_q,
                                block_kv=cfg.attn_block_kv,
                                q_offset=q_offset)
    o = L.attn_out(p["attn"], o, cfg)
    if cfg.post_norms:
        o = L.rms_norm(o, p["ln1_post"], eps=cfg.norm_eps)
    return o, (k, v)


def _ffn_block(p, h, cfg: ArchConfig):
    m_in = L.rms_norm(h, p["ln2"], eps=cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = MOE.moe_apply(p["moe"], m_in, cfg)
    else:
        m, aux = L.mlp_apply(p["mlp"], m_in, act=cfg.act), {}
    if cfg.post_norms:
        m = L.rms_norm(m, p["ln2_post"], eps=cfg.norm_eps)
    return m, aux


def _embed_tokens(params, cfg: ArchConfig, batch):
    h = L.embed_lookup(params["embed"], batch["tokens"], cfg.compute_dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        # stub frontend: merge precomputed patch embeddings at masked positions
        mask = batch["vision_mask"]                       # (B, S) bool
        vis_idx = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1
        vis_idx = jnp.clip(vis_idx, 0, batch["vision_embeds"].shape[1] - 1)
        vis = jnp.take_along_axis(
            batch["vision_embeds"].astype(cfg.compute_dtype),
            vis_idx[..., None], axis=1)
        h = jnp.where(mask[..., None], vis, h)
    return h


def forward(params, cfg: ArchConfig, batch):
    """Training/eval forward. batch: tokens (B, S) [+ positions / vision].
    Returns logits (B, S, padded_vocab)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed_tokens(params, cfg, batch)
    h = _res_constrain(h, cfg)

    if cfg.mrope_sections:
        positions = batch["positions"]                    # (B, 3, S)
    else:
        positions = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = _rope_for(cfg, positions)

    pat = layer_pattern(cfg)

    def group_body(h, group_params):
        def inner(h, group_params):
            for i, window in enumerate(pat):
                p = group_params[i]
                o, _ = _attn_block(p, h, cfg, cos, sin, window)
                h = _res_constrain(h + o, cfg)
                m, _ = _ffn_block(p, h, cfg)
                h = _res_constrain(h + m, cfg)
            return h
        if cfg.remat:
            inner = jax.checkpoint(inner)
        return inner(h, group_params), None

    # params["layers"] is a list (len pat) of stacked dicts -> rearrange for scan
    stacked = params["layers"]
    h, _ = jax.lax.scan(lambda hh, gp: group_body(hh, gp), h, stacked)

    h = L.rms_norm(h, params["ln_f"], eps=cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = L.unembed(h, table, cap=cfg.logit_softcap)
    return constrain(logits, "batch", None, "model")


def loss_fn(params, cfg: ArchConfig, batch):
    logits = forward(params, cfg, batch)
    return L.cross_entropy(logits, batch["labels"], vocab=cfg.vocab)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def _cache_layout(cfg: ArchConfig, B: int, S_max: int):
    hd = cfg.resolved_head_dim
    return jax.ShapeDtypeStruct((cfg.n_layers, B, S_max, cfg.n_kv_heads, hd),
                                jnp.bfloat16)


def init_cache(cfg: ArchConfig, B: int, S_max: int):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, B, S_max, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, cfg: ArchConfig, batch, *, cache_len: Optional[int] = None):
    """Run the prompt through the stack, filling a KV cache of length
    cache_len (>= S). Returns (last-position logits, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    S_max = cache_len or S
    h = _embed_tokens(params, cfg, batch)
    if cfg.mrope_sections:
        positions = batch["positions"]
    else:
        positions = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = _rope_for(cfg, positions)
    pat = layer_pattern(cfg)

    def group_body(h, group_params):
        ks, vs = [], []
        for i, window in enumerate(pat):
            p = group_params[i]
            o, (k, v) = _attn_block(p, h, cfg, cos, sin, window)
            h = constrain(h + o, "batch", None, None)
            m, _ = _ffn_block(p, h, cfg)
            h = constrain(h + m, "batch", None, None)
            ks.append(k)
            vs.append(v)
        return h, (jnp.stack(ks), jnp.stack(vs))          # (pat, B, S, KH, hd)

    h, (k_all, v_all) = jax.lax.scan(group_body, h, params["layers"])
    # (G, pat, B, S, KH, hd) -> (L, B, S_max, KH, hd)
    def fix(x):
        x = x.reshape(cfg.n_layers, B, S, cfg.n_kv_heads, -1)
        pad = S_max - S
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))) \
            .astype(jnp.bfloat16)
    cache = {"k": fix(k_all), "v": fix(v_all),
             "pos": jnp.asarray(S, jnp.int32)}

    h = L.rms_norm(h[:, -1:], params["ln_f"], eps=cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = L.unembed(h, table, cap=cfg.logit_softcap)
    return logits[:, 0], cache


def decode_step(params, cfg: ArchConfig, token, cache, *, positions=None):
    """One decode step. token (B, 1) int32; cache from init_cache/prefill.
    Returns (logits (B, vocab), new cache)."""
    B = token.shape[0]
    pos = cache["pos"]
    batch = {"tokens": token}
    if cfg.family == "vlm":
        pos3 = positions if positions is not None \
            else jnp.broadcast_to(pos, (B, 3, 1)).astype(jnp.int32)
        cos, sin = _rope_for(cfg, pos3)
    else:
        cos, sin = _rope_for(cfg, jnp.full((B, 1), pos, jnp.int32))
    h = _embed_tokens(params, cfg, batch)

    pat = layer_pattern(cfg)
    G = n_groups(cfg)

    def fold(x):  # (L, ...) -> (G, pat, ...)
        return x.reshape((G, len(pat)) + x.shape[1:])

    k_cache, v_cache = fold(cache["k"]), fold(cache["v"])

    def group_body(h, xs):
        group_params, k_g, v_g = xs
        k_out, v_out = [], []
        for i, window in enumerate(pat):
            p = group_params[i]
            a_in = L.rms_norm(h, p["ln1"], eps=cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], a_in, cfg, cos, sin)
            k_i = jax.lax.dynamic_update_slice_in_dim(
                k_g[i], k.astype(jnp.bfloat16), pos, axis=1)
            v_i = jax.lax.dynamic_update_slice_in_dim(
                v_g[i], v.astype(jnp.bfloat16), pos, axis=1)
            o = L.decode_attention(q, k_i, v_i, pos + 1, window=window,
                                   cap=cfg.attn_softcap)
            o = L.attn_out(p["attn"], o, cfg)
            if cfg.post_norms:
                o = L.rms_norm(o, p["ln1_post"], eps=cfg.norm_eps)
            h = constrain(h + o, "batch", None, None)
            m, _ = _ffn_block(p, h, cfg)
            h = constrain(h + m, "batch", None, None)
            k_out.append(k_i)
            v_out.append(v_i)
        return h, (jnp.stack(k_out), jnp.stack(v_out))

    h, (k_new, v_new) = jax.lax.scan(
        group_body, h, (params["layers"], k_cache, v_cache))

    h = L.rms_norm(h, params["ln_f"], eps=cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = L.unembed(h, table, cap=cfg.logit_softcap)

    def unfold(x):
        return x.reshape((cfg.n_layers,) + x.shape[2:])

    new_cache = {"k": unfold(k_new), "v": unfold(v_new), "pos": pos + 1}
    return logits[:, 0], new_cache
