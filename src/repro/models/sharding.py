"""Mesh-context sharding constraints for model code.

Model functions call `constrain(x, "batch", None, "model")` with LOGICAL axis
names; if a mesh context is active (set by the launcher) the constraint is
applied, otherwise it is a no-op — so the same model code runs in single-device
tests and in the 512-chip dry-run.

Logical -> physical mapping: "batch" -> every pod/data axis present in the
mesh; "model" -> the model axis; "data" -> the data axes only (sequence
parallelism); None -> replicated.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _ctx.mesh = mesh
    try:
        yield
    finally:
        _ctx.mesh = prev


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def resolve(mesh: Mesh, *logical) -> P:
    phys = []
    for ax in logical:
        if ax is None:
            phys.append(None)
        elif ax == "batch":
            phys.append(batch_axes(mesh))
        elif ax == "data":
            phys.append(tuple(a for a in ("data",) if a in mesh.axis_names))
        elif ax == "model":
            phys.append("model" if "model" in mesh.axis_names else None)
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return P(*phys)


def constrain(x, *logical):
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(mesh, *logical)))


def sharding(mesh: Mesh, *logical) -> NamedSharding:
    return NamedSharding(mesh, resolve(mesh, *logical))
