"""Lloyd iterations (the clustering phase). The paper keeps this identical to
standard k-means; the loop itself lives in ``repro.core.engine`` behind the
Backend protocol — this module keeps the historical ``assign``/``update``/
``lloyd``/``kmeans`` entry points as thin shims over it."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine import (FusedBackend, LloydResult, PallasBackend,
                               centroid_means, make_backend, segment_update)

__all__ = ["LloydResult", "assign", "update", "lloyd", "kmeans"]


def assign(points: jax.Array, centroids: jax.Array, *, block: int = 4096,
           use_pallas: bool = False) -> tuple[jax.Array, jax.Array]:
    """Assignment step: nearest centroid per point. Returns (assignment, min_d2).

    Blocked over points so the (n, k) distance matrix never materializes whole
    (the TPU kernel tiles the same way: point tiles streamed, centroids resident).
    """
    if use_pallas:
        from repro.kernels import ops as kops
        a, md, _, _ = kops.lloyd_assign(points, centroids)
        return a, md
    return engine.assign_blocked(points, centroids, block=block)


def update(points: jax.Array, assignment: jax.Array, k: int,
           weights: Optional[jax.Array] = None,
           prev_centroids: Optional[jax.Array] = None) -> jax.Array:
    """Update step: per-cluster (weighted) means via segment-sum. Empty clusters
    keep their previous centroid (the standard production fallback)."""
    sums, counts = segment_update(points, assignment, k, weights)
    return centroid_means(sums, counts, prev_centroids)


@functools.partial(jax.jit, static_argnames=("max_iters", "block", "use_pallas"))
def lloyd(points: jax.Array, init_centroids: jax.Array, *, max_iters: int = 50,
          tol: float = 1e-6, weights: Optional[jax.Array] = None,
          block: int = 4096, use_pallas: bool = False) -> LloydResult:
    """Run Lloyd iterations until the inertia improvement falls below `tol`
    (relative) or `max_iters` is hit. The k-means potential is monotonically
    non-increasing — a property test asserts this."""
    backend = PallasBackend() if use_pallas else FusedBackend(block=block)
    return engine.fit_points(points, init_centroids, weights, backend,
                             max_iters, tol)


def kmeans(key: jax.Array, points: jax.Array, k: int, *, init: str = "kmeans++",
           variant: str = "fused", max_iters: int = 50,
           use_pallas: bool = False) -> LloydResult:
    """End-to-end k-means: seeding (paper's phase) + Lloyd clustering."""
    if init == "kmeans++":
        from repro.core.kmeanspp import kmeanspp
        seeds = kmeanspp(key, points, k, variant=variant).centroids
    elif init == "kmeans||":
        from repro.core.kmeans_parallel import kmeans_parallel_init
        seeds = kmeans_parallel_init(key, points, k,
                                     backend=make_backend(variant)).centroids
    elif init == "random":
        from repro.core.kmeanspp import random_init
        seeds = random_init(key, points, k).centroids
    else:
        raise ValueError(f"unknown init {init!r}")
    return lloyd(points, seeds, max_iters=max_iters, use_pallas=use_pallas)
