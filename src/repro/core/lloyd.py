"""Lloyd iterations (the clustering phase). The paper keeps this identical to
standard k-means; we provide a blocked, weighted implementation plus the fused
Pallas assignment kernel for the hot path."""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kmeanspp import pairwise_d2


class LloydResult(NamedTuple):
    centroids: jax.Array      # (k, d)
    assignment: jax.Array     # (n,) int32
    inertia: jax.Array        # () sum of squared distances to assigned centroid
    n_iters: jax.Array        # () int32


def assign(points: jax.Array, centroids: jax.Array, *, block: int = 4096,
           use_pallas: bool = False) -> tuple[jax.Array, jax.Array]:
    """Assignment step: nearest centroid per point. Returns (assignment, min_d2).

    Blocked over points so the (n, k) distance matrix never materializes whole
    (the TPU kernel tiles the same way: point tiles streamed, centroids resident).
    """
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.lloyd_assign(points, centroids)

    n, d = points.shape
    pad = (-n) % block
    pts = jnp.pad(points, ((0, pad), (0, 0)))

    def blk(x):
        d2 = pairwise_d2(x.astype(jnp.float32), centroids.astype(jnp.float32))
        a = jnp.argmin(d2, axis=1).astype(jnp.int32)
        return a, jnp.min(d2, axis=1)

    a, m = jax.lax.map(blk, pts.reshape(-1, block, d))
    return a.reshape(-1)[:n], m.reshape(-1)[:n]


def update(points: jax.Array, assignment: jax.Array, k: int,
           weights: Optional[jax.Array] = None,
           prev_centroids: Optional[jax.Array] = None) -> jax.Array:
    """Update step: per-cluster (weighted) means via segment-sum. Empty clusters
    keep their previous centroid (the standard production fallback)."""
    pts = points.astype(jnp.float32)
    w = jnp.ones((points.shape[0],), jnp.float32) if weights is None else weights
    sums = jax.ops.segment_sum(pts * w[:, None], assignment, num_segments=k)
    counts = jax.ops.segment_sum(w, assignment, num_segments=k)
    means = sums / jnp.maximum(counts, 1e-12)[:, None]
    if prev_centroids is not None:
        means = jnp.where((counts > 0)[:, None], means,
                          prev_centroids.astype(jnp.float32))
    return means


@functools.partial(jax.jit, static_argnames=("max_iters", "block", "use_pallas"))
def lloyd(points: jax.Array, init_centroids: jax.Array, *, max_iters: int = 50,
          tol: float = 1e-6, weights: Optional[jax.Array] = None,
          block: int = 4096, use_pallas: bool = False) -> LloydResult:
    """Run Lloyd iterations until the inertia improvement falls below `tol`
    (relative) or `max_iters` is hit. The k-means potential is monotonically
    non-increasing — a property test asserts this."""
    k = init_centroids.shape[0]

    def cond(state):
        i, _, prev_inertia, inertia, _ = state
        rel = (prev_inertia - inertia) / jnp.maximum(prev_inertia, 1e-30)
        return jnp.logical_and(i < max_iters,
                               jnp.logical_or(i < 2, rel > tol))

    def body(state):
        i, cents, _, inertia, _ = state
        a, m = assign(points, cents, block=block, use_pallas=use_pallas)
        w = m if weights is None else m * weights
        new_inertia = jnp.sum(w)
        new_cents = update(points, a, k, weights=weights, prev_centroids=cents)
        return i + 1, new_cents, inertia, new_inertia, a

    n = points.shape[0]
    init = (jnp.zeros((), jnp.int32), init_centroids.astype(jnp.float32),
            jnp.inf, jnp.inf, jnp.zeros((n,), jnp.int32))
    i, cents, _, inertia, a = jax.lax.while_loop(cond, body, init)
    return LloydResult(cents.astype(points.dtype), a, inertia, i)


def kmeans(key: jax.Array, points: jax.Array, k: int, *, init: str = "kmeans++",
           variant: str = "fused", max_iters: int = 50,
           use_pallas: bool = False) -> LloydResult:
    """End-to-end k-means: seeding (paper's phase) + Lloyd clustering."""
    from repro.core.kmeanspp import kmeanspp as _kmeanspp, random_init
    if init == "kmeans++":
        seeds = _kmeanspp(key, points, k, variant=variant).centroids
    elif init == "kmeans||":
        from repro.core.kmeans_parallel import kmeans_parallel_init
        seeds = kmeans_parallel_init(key, points, k).centroids
    elif init == "random":
        seeds = random_init(key, points, k).centroids
    else:
        raise ValueError(f"unknown init {init!r}")
    return lloyd(points, seeds, max_iters=max_iters, use_pallas=use_pallas)
