"""Clustering-quality metrics — the paper's claim is speedup *while maintaining
the quality of the serial algorithm*; these are what the parity bench asserts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kmeanspp import pairwise_d2


def inertia(points: jax.Array, centroids: jax.Array, *, block: int = 8192) -> jax.Array:
    """Sum over points of squared distance to the nearest centroid (phi)."""
    n, d = points.shape
    pad = (-n) % block
    pts = jnp.pad(points.astype(jnp.float32), ((0, pad), (0, 0)))
    c = centroids.astype(jnp.float32)

    def blk(x):
        return jnp.sum(jnp.min(pairwise_d2(x, c), axis=1))

    parts = jax.lax.map(blk, pts.reshape(-1, block, d))
    # padded zeros contribute their distance to the nearest centroid — subtract
    pad_contrib = blk(jnp.zeros((1, d), jnp.float32))[None] * 0  # shape helper
    total = jnp.sum(parts)
    if pad:
        total = total - jnp.min(jnp.sum(c * c, axis=1)) * pad
    return total


def quantization_error(points: jax.Array, centroids: jax.Array) -> jax.Array:
    """Mean squared quantization error (inertia / n) — used by KV-PQ."""
    return inertia(points, centroids) / points.shape[0]


def cluster_sizes(assignment: jax.Array, k: int) -> jax.Array:
    return jax.ops.segment_sum(jnp.ones_like(assignment, jnp.float32),
                               assignment, num_segments=k)


def balance(assignment: jax.Array, k: int) -> jax.Array:
    """Load-balance statistic max/mean cluster size (1.0 = perfectly balanced).
    Used to evaluate kmeans++ MoE router init vs random init."""
    sizes = cluster_sizes(assignment, k)
    return jnp.max(sizes) / jnp.maximum(jnp.mean(sizes), 1e-12)
