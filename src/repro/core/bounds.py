"""Exact per-tile bound state for the seeding round (Raff 2021 / Capó 2018).

A seeding round folds the new centroid(s) ``c_new`` into every point's D².
A point x can only improve when ``d(x, c) < d(x, nearest-so-far)``, so by the
triangle inequality a whole *tile* of points provably cannot change when

    d(center_t, c) - r_t  >=  sqrt(max_{x in tile} min_d2[x])

where ``center_t`` is the tile's ball center and ``r_t`` its radius
(``d(x, c) >= d(center_t, c) - d(x, center_t) >= d(center_t, c) - r_t``).
Skipping such a tile is *exact*: its ``min_d2`` entries, and therefore its
per-tile partial sum, are bitwise what a full recompute would produce
(``min(md, d2)`` returns ``md`` whenever ``d2 >= md``), so the tiled sampler
composes unchanged. Capó et al. motivate this granularity: block-level — not
per-point — pruning is what pays at massive n, and the tile is exactly the
unit the ``SeedRound`` partials machinery already tracks.

The bound is evaluated in fp32, so a small conservative ``_SLACK`` keeps
rounding from ever skipping a tile the exact-arithmetic bound would keep
(erring toward "compute it" never changes results, only saves less).

This module is pure jnp: the reference/fused backends use it directly (the
skip logic is therefore covered by the distribution/parity tests), and the
Pallas backend uses :func:`active_tiles` to build the compacted active-tile
index map its gated kernel prefetches.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# Head-room on the skip threshold. The kernels (and the bound itself)
# evaluate D^2 in the matmul form ||x||^2 - 2x.c + ||c||^2, whose fp32
# cancellation error is ABSOLUTE in the magnitude of the operands: about
# eps_f32 * (||x|| + ||c||)^2, NOT eps * d^2. A purely relative slack would
# therefore under-protect data far from the origin. _REL covers the relative
# rounding of the comparison chain; _ABS scales a per-tile magnitude term
# (||center|| + r + max||c||)^2 with ~80x head-room over eps_f32 = 1.2e-7,
# so a tile is only ever skipped when the kernel's OWN fp32 d2 provably
# cannot dip below the carried min_d2 (skipping stays bitwise exact; far
# from the origin the gate just prunes less — center your data for the best
# skip rate).
_REL = 1e-6
_ABS = 1e-5


class RoundCache(NamedTuple):
    """Per-dataset state computed ONCE per seed/fit call (the prologue).

    ``norms`` feeds the matmul-form distance (``||x||² - 2x·c + ||c||²``) so
    the round kernels stop recomputing ``||x||²`` every round; it is always
    fp32 even when the points stream as bf16. ``centers``/``radii`` are the
    tile centroid-balls the skip bound needs; they are ``None`` when bound
    gating is disabled (norm caching alone does not need them).
    """

    norms: jax.Array                       # (n,) fp32 ||x||²
    centers: Optional[jax.Array] = None    # (n_tiles, d) fp32 tile means
    radii: Optional[jax.Array] = None      # (n_tiles,) fp32 ball radii


class RoundState(NamedTuple):
    """Loop-carried bound state: the previous round's per-tile partial sums
    (reused verbatim for skipped tiles) and per-tile max of ``min_d2``."""

    partials: jax.Array                    # (n_tiles,) fp32
    tile_max: jax.Array                    # (n_tiles,) fp32


def point_norms(points: jax.Array) -> jax.Array:
    """fp32 ``||x||²`` per row — THE quantity the prologue caches."""
    x = points.astype(jnp.float32)
    return jnp.sum(x * x, axis=-1)


def tile_counts(n: int, block_n: int) -> jax.Array:
    """Valid-row count of each tile of a zero-padded (n,) -> (n_tiles, bn)."""
    n_tiles = -(-n // block_n)
    start = jnp.arange(n_tiles, dtype=jnp.int32) * block_n
    return jnp.clip(n - start, 0, block_n).astype(jnp.float32)


def prologue(points: jax.Array, block_n: int, *,
             with_bounds: bool = True) -> RoundCache:
    """Pure-jnp prologue: cached norms (+ tile centers/radii for the bound).

    Padded tail rows are excluded from center/radius (a zero pad row could
    otherwise inflate the tail tile's ball). The Pallas backend computes the
    same three arrays in one fused kernel pass (`seed_prologue_pallas`);
    cross-backend users only need the *norms* to agree bitwise — the bound
    geometry may differ in ulps without affecting results (the bound is a
    sufficient condition, never a value).
    """
    pts = points.astype(jnp.float32)
    n, d = pts.shape
    norms = jnp.sum(pts * pts, axis=1)
    if not with_bounds:
        return RoundCache(norms)
    pad = (-n) % block_n
    xp = jnp.pad(pts, ((0, pad), (0, 0))).reshape(-1, block_n, d)
    cnt = tile_counts(n, block_n)                       # (n_tiles,)
    centers = xp.sum(axis=1) / jnp.maximum(cnt, 1.0)[:, None]
    d2c = jnp.sum((xp - centers[:, None, :]) ** 2, axis=-1)  # (n_tiles, bn)
    row = jnp.arange(block_n)[None, :] < cnt[:, None]
    radii = jnp.sqrt(jnp.max(jnp.where(row, d2c, 0.0), axis=1))
    return RoundCache(norms, centers, radii)


def active_tiles(c_new: jax.Array, cache: RoundCache,
                 tile_max: jax.Array) -> jax.Array:
    """(n_tiles,) bool — True where the tile MIGHT change this round.

    ``c_new`` is the round's (m, d) new-centroid block; a tile is skipped
    only when ``(d(center_t, c) - r_t)^2 >= tile_max_t`` against its
    *nearest* new centroid with the conservative fp32 margin described at
    ``_REL``/``_ABS`` (rounding can only keep a tile active, never skip a
    changeable one)."""
    c = c_new.astype(jnp.float32)
    cn = jnp.sum(c * c, axis=-1)
    ctr = cache.centers
    ctr_n2 = jnp.sum(ctr * ctr, axis=1)
    dot = jax.lax.dot_general(ctr, c, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    d2 = jnp.maximum(ctr_n2[:, None] - 2.0 * dot + cn[None, :], 0.0)
    dc = jnp.sqrt(jnp.min(d2, axis=1))                  # nearest new centroid
    lo = jnp.maximum(dc - cache.radii, 0.0)             # min dist to tile
    # magnitude of the operands feeding the kernels' matmul-form d2 for this
    # tile: every ||x|| is within ||center|| + r, every ||c|| within cmax
    cmax = jnp.sqrt(jnp.max(cn))
    scale = (jnp.sqrt(ctr_n2) + cache.radii + cmax) ** 2
    skip = lo * lo >= tile_max * (1.0 + _REL) + _ABS * scale
    return jnp.logical_not(skip)


def expand_mask(active: jax.Array, block_n: int, n: int) -> jax.Array:
    """Per-tile mask -> per-point mask (first n entries). Broadcast+reshape,
    NOT jnp.repeat: repeat lowers to a full-n cumsum, which would put an O(n)
    scan back into the jaxpr the tiled sampler is pinned to avoid."""
    n_tiles = active.shape[0]
    return jnp.broadcast_to(active[:, None],
                            (n_tiles, block_n)).reshape(-1)[:n]


def tile_reduce_max(x: jax.Array, block_n: int) -> jax.Array:
    """Per-tile max of a non-negative (n,) array (zero-padded tail) — the
    bound-state twin of ``sampling.tile_partials``."""
    n = x.shape[0]
    pad = (-n) % block_n
    xp = x if pad == 0 else jnp.pad(x, (0, pad))
    return xp.reshape(-1, block_n).max(axis=1)


def compact_ids(active: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Compaction for the scalar-prefetched index map: returns
    ``(ids_clamped (n_tiles,) int32, n_active () int32)``.

    ``ids_clamped[i]`` is the i-th active tile id for ``i < n_active`` and the
    LAST active tile id after that, so the trailing grid steps of the gated
    kernel revisit an already-resident block (no extra HBM fetch) and are
    compute-gated off by ``i < n_active``. Stable argsort keeps active tiles
    in ascending order, preserving the pipeline's sequential-stream access
    pattern over the survivors.

    ``n_active`` is floored at 1 even when every tile clears the bound:
    grid step 0 then recomputes one skippable tile, which is a value-noop
    (skipping is exact) but guarantees every VISITED output block gets
    written — a compiled-Mosaic output block is write-only VMEM, so a
    visited-but-never-written block would flush garbage over the aliased
    buffer. Unvisited blocks are safe: the alias means their HBM contents
    are the donated inputs, untouched.
    """
    n_tiles = active.shape[0]
    order = jnp.argsort(jnp.logical_not(active), stable=True).astype(jnp.int32)
    n_active = jnp.maximum(jnp.sum(active), 1).astype(jnp.int32)
    clamp = jnp.minimum(jnp.arange(n_tiles, dtype=jnp.int32), n_active - 1)
    return order[clamp], n_active
