"""Exact TWO-LEVEL bound state shared by the SEEDING and ASSIGNMENT rounds
(Raff 2021 / Capó 2018): per-tile ball/gap bounds at the coarse level,
per-POINT Hamerly bounds at the fine level, and a tile → super-tile →
global accumulator hierarchy.

Seeding bound. A seeding round folds the new centroid(s) ``c_new`` into every
point's D². A point x can only improve when ``d(x, c) < d(x,
nearest-so-far)``, so by the triangle inequality a whole *tile* of points
provably cannot change when

    d(center_t, c) - r_t  >=  sqrt(max_{x in tile} min_d2[x])

where ``center_t`` is the tile's ball center and ``r_t`` its radius
(``d(x, c) >= d(center_t, c) - d(x, center_t) >= d(center_t, c) - r_t``).
Skipping such a tile is *exact*: its ``min_d2`` entries, and therefore its
per-tile partial sum, are bitwise what a full recompute would produce
(``min(md, d2)`` returns ``md`` whenever ``d2 >= md``), so the tiled sampler
composes unchanged. Capó et al. motivate this granularity: block-level — not
per-point — pruning is what pays at massive n, and the tile is exactly the
unit the ``SeedRound`` partials machinery already tracks.

Assignment (Lloyd) bound. Between iterations every centroid moves by
``delta_j = ‖c_j^{t+1} − c_j^t‖``. For a point x assigned to j0 with
second-best margin ``gap(x) = d(x, c_2nd) − d(x, c_j0)``, no label can change
as long as ``gap(x) >= delta_j0 + max_j delta_j`` (its own centroid ran away
by at most delta_j0, the best challenger closed by at most max delta). The
tile-level state carries ``tile_gap = min_x gap(x)``. Skipping a tile keeps
the carried assignment AND the carried ``min_d2``/per-cluster sums bitwise
exact only when the centroids the tile is assigned to did not move at all —
so the gate additionally requires ``delta_j == 0`` for every cluster the
tile's carried counts mark as occupied (near convergence most clusters stop
moving bitwise, which is exactly when the assignment round becomes pure
re-verification). A skipped tile's carried gap is decayed by that
iteration's ``max_j delta_j`` (:func:`decay_gap`), which keeps it a valid
lower bound across consecutive skips.

Per-point (fine-level) bounds. Inside a tile the coarse gate keeps ACTIVE,
most points may still be provably stable. Two Hamerly-style per-point bounds
prune them:

* ASSIGNMENT: ``ub[i] = sqrt(min_d2[i])`` is the EXACT distance to the
  assigned centroid (not just a bound — the exactness discipline below keeps
  ``min_d2`` exact through pruned stretches, so ``ub`` needs no storage of
  its own), and ``point_lb[i]`` is a lower bound on the second-nearest
  distance, decayed by each iteration's ``max_j delta_j``. A point
  short-circuits the k-way distance recomputation iff its OWN centroid is
  bitwise unmoved (``delta_{a(i)} == 0``) and ``point_lb[i] − ub[i] >=
  delta_max`` (with the fp32 margin): the label provably cannot change AND
  the carried ``min_d2[i]`` is bitwise what a recompute would produce (the
  matmul-form d2 of column j is elementwise in ``c_j``). The decay is
  tracked LAZILY per tile (``lb_debt``): skipped tiles pay no O(n) update —
  the debt folds into the prune threshold and is absorbed into the stored
  ``point_lb`` the next time the tile computes.
* SEEDING (Raff-style): the prologue caches ``center_d[i] = d(x_i,
  center_{t(i)})`` once per call; a seed round with new centroid c has
  ``d(x_i, c) >= dc_t − center_d[i]`` (one fresh O(n_tiles) distance
  ``dc_t = d(center_t, c)`` per round), so points with ``(dc_t −
  center_d[i])² >= min_d2[i]`` (plus margin) provably cannot improve and
  the min-update is skipped — a value-noop by construction (``min(md, d2)``
  returns ``md`` whenever ``d2 >= md``).

Hierarchical accumulators. The tiled assignment round used to materialize
per-TILE per-cluster sums/counts — O(n_tiles·k·d) HBM. The accumulators are
now per-SUPER-TILE (``tiles_per_super ≈ √n_tiles`` consecutive tiles share
one ``(k, d)`` slot, accumulated sequentially in ascending tile order inside
the kernel), capping the footprint at O(n_super·k·d). Aliasing — the carry
for skipped work — moves to the super level: a super-tile's accumulator
block is carried iff ALL its tiles are skipped, so the coarse gate is
expanded to whole super-tiles (``expand_active_supers``). A tile
force-activated only by its super is a value-noop (skipping was exact), and
its points are exactly the ones the fine-level per-point gate prunes — the
two levels compose.

The bounds are evaluated in fp32, so small conservative slacks keep rounding
from ever skipping a tile (or point) the exact-arithmetic bound would keep
(erring toward "compute it" never changes results, only saves less).

This module is pure jnp: the reference/fused backends use it directly (the
skip logic is therefore covered by the distribution/parity tests), and the
Pallas backend uses :func:`active_tiles` / :func:`assign_active_tiles` to
build the compacted active-tile index maps its gated kernels prefetch.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# Head-room on the skip threshold. The kernels (and the bound itself)
# evaluate D^2 in the matmul form ||x||^2 - 2x.c + ||c||^2, whose fp32
# cancellation error is ABSOLUTE in the magnitude of the operands: about
# eps_f32 * (||x|| + ||c||)^2, NOT eps * d^2. A purely relative slack would
# therefore under-protect data far from the origin. _REL covers the relative
# rounding of the comparison chain; _ABS scales a per-tile magnitude term
# (||center|| + r + max||c||)^2 with ~80x head-room over eps_f32 = 1.2e-7,
# so a tile is only ever skipped when the kernel's OWN fp32 d2 provably
# cannot dip below the carried min_d2 (skipping stays bitwise exact; far
# from the origin the gate just prunes less — center your data for the best
# skip rate).
_REL = 1e-6
_ABS = 1e-5
# Distance-unit analogue of _ABS for the ASSIGNMENT gate: the per-point gap
# is a difference of square roots of matmul-form d2 values, and near-zero
# distances turn the absolute d2 error into ~sqrt(_ABS) of distance error —
# so the gap margin scales sqrt(_ABS)-sized head-room by the tile's
# distance-unit operand magnitude.
_ABS_GAP = 4e-3


class RoundCache(NamedTuple):
    """Per-dataset state computed ONCE per seed/fit call (the prologue).

    ``norms`` feeds the matmul-form distance (``||x||² - 2x·c + ||c||²``) so
    the round kernels stop recomputing ``||x||²`` every round; it is always
    fp32 even when the points stream as bf16. ``centers``/``radii`` are the
    tile centroid-balls the skip bound needs; they are ``None`` when bound
    gating is disabled (norm caching alone does not need them).
    """

    norms: jax.Array                       # (n,) fp32 ||x||²
    centers: Optional[jax.Array] = None    # (n_tiles, d) fp32 tile means
    radii: Optional[jax.Array] = None      # (n_tiles,) fp32 ball radii
    center_d: Optional[jax.Array] = None   # (n,) fp32 d(x, tile center) —
                                           # the per-point seeding bound


class BoundState(NamedTuple):
    """Loop-carried bound state, unified across the two round primitives.

    The SEEDING loop carries ``(partials, tile_max)``: the previous round's
    per-tile partial sums (reused verbatim for skipped tiles) and per-tile
    max of ``min_d2`` (the skip bound's RHS).

    The ASSIGNMENT (Lloyd) loop carries ``(partials, tile_gap, tile_sums,
    tile_counts, assignment, min_d2, point_lb, lb_debt)``: per-tile inertia
    partials, the per-tile second-best margin (in DISTANCE units — the
    movement bound's LHS), the per-SUPER-TILE per-cluster sums/counts whose
    super-axis reduction is the centroid update (the hierarchical
    accumulators — ``tiles_per_super`` consecutive tiles share one slot),
    the per-point labels/D² that skipped tiles carry verbatim (the gated
    kernel's aliased buffers), the per-point Hamerly lower bound on the
    second-nearest distance, and the per-tile lazy movement debt the stored
    ``point_lb`` is stale by. The per-tile ball geometry both gates compare
    against lives in the once-per-call :class:`RoundCache`; the movement
    ``delta_j`` is derived each iteration from the loop's own consecutive
    centroids. Fields a loop does not use stay ``None`` (they are
    pytree-static).
    """

    partials: jax.Array                        # (n_tiles,) fp32
    tile_max: Optional[jax.Array] = None       # (n_tiles,) fp32 (seeding)
    tile_gap: Optional[jax.Array] = None       # (n_tiles,) fp32 (assignment)
    tile_sums: Optional[jax.Array] = None      # (n_super, k, d) fp32
    tile_counts: Optional[jax.Array] = None    # (n_super, k) fp32
    assignment: Optional[jax.Array] = None     # (n,) int32 (assignment)
    min_d2: Optional[jax.Array] = None         # (n,) fp32 (assignment)
    point_lb: Optional[jax.Array] = None       # (n,) fp32 Hamerly lower
                                               # bound on 2nd-nearest dist
    lb_debt: Optional[jax.Array] = None        # (n_tiles,) fp32 movement
                                               # debt of the stored point_lb


# historical name (PR 3's seeding-only state) — same type, seed-field layout
RoundState = BoundState


def point_norms(points: jax.Array) -> jax.Array:
    """fp32 ``||x||²`` per row — THE quantity the prologue caches."""
    x = points.astype(jnp.float32)
    return jnp.sum(x * x, axis=-1)


def tile_counts(n: int, block_n: int) -> jax.Array:
    """Valid-row count of each tile of a zero-padded (n,) -> (n_tiles, bn)."""
    n_tiles = -(-n // block_n)
    start = jnp.arange(n_tiles, dtype=jnp.int32) * block_n
    return jnp.clip(n - start, 0, block_n).astype(jnp.float32)


def prologue(points: jax.Array, block_n: int, *,
             with_bounds: bool = True) -> RoundCache:
    """Pure-jnp prologue: cached norms (+ tile centers/radii for the bound).

    Padded tail rows are excluded from center/radius (a zero pad row could
    otherwise inflate the tail tile's ball). The Pallas backend computes the
    same three arrays in one fused kernel pass (`seed_prologue_pallas`);
    cross-backend users only need the *norms* to agree bitwise — the bound
    geometry may differ in ulps without affecting results (the bound is a
    sufficient condition, never a value).
    """
    pts = points.astype(jnp.float32)
    n, d = pts.shape
    norms = jnp.sum(pts * pts, axis=1)
    if not with_bounds:
        return RoundCache(norms)
    pad = (-n) % block_n
    xp = jnp.pad(pts, ((0, pad), (0, 0))).reshape(-1, block_n, d)
    cnt = tile_counts(n, block_n)                       # (n_tiles,)
    centers = xp.sum(axis=1) / jnp.maximum(cnt, 1.0)[:, None]
    d2c = jnp.sum((xp - centers[:, None, :]) ** 2, axis=-1)  # (n_tiles, bn)
    row = jnp.arange(block_n)[None, :] < cnt[:, None]
    radii = jnp.sqrt(jnp.max(jnp.where(row, d2c, 0.0), axis=1))
    center_d = jnp.sqrt(jnp.maximum(d2c, 0.0)).reshape(-1)[:n]
    return RoundCache(norms, centers, radii, center_d)


def seed_gate(c_new: jax.Array, cache: RoundCache,
              tile_max: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Both levels of the SEEDING gate, one O(n_tiles·m) pass.

    Returns ``(active, dc, margin)``:

    * ``active`` (n_tiles,) bool — True where the tile MIGHT change this
      round: a tile is skipped only when ``(d(center_t, c) - r_t)^2 >=
      tile_max_t`` against its *nearest* new centroid with the conservative
      fp32 margin described at ``_REL``/``_ABS`` (rounding can only keep a
      tile active, never skip a changeable one).
    * ``dc`` (n_tiles,) fp32 — distance of each tile ball center to its
      nearest new centroid. Inside an ACTIVE tile, a point x with
      ``(dc_t − center_d[x])² >= min_d2[x]·(1+_REL) + margin_t`` provably
      cannot improve (``d(x, c) >= dc_t − d(x, center_t)``) — the fine,
      per-point level of the same bound, using the prologue-cached
      ``center_d`` instead of the ball radius.
    * ``margin`` (n_tiles,) fp32 — the ``_ABS``-scaled absolute slack term
      the per-point test adds (same operand-magnitude model as the tile
      test, streamed to the kernels as one per-tile scalar)."""
    c = c_new.astype(jnp.float32)
    cn = jnp.sum(c * c, axis=-1)
    ctr = cache.centers
    ctr_n2 = jnp.sum(ctr * ctr, axis=1)
    dot = jax.lax.dot_general(ctr, c, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    d2 = jnp.maximum(ctr_n2[:, None] - 2.0 * dot + cn[None, :], 0.0)
    dc = jnp.sqrt(jnp.min(d2, axis=1))                  # nearest new centroid
    lo = jnp.maximum(dc - cache.radii, 0.0)             # min dist to tile
    # magnitude of the operands feeding the kernels' matmul-form d2 for this
    # tile: every ||x|| is within ||center|| + r, every ||c|| within cmax
    cmax = jnp.sqrt(jnp.max(cn))
    margin = _ABS * (jnp.sqrt(ctr_n2) + cache.radii + cmax) ** 2
    skip = lo * lo >= tile_max * (1.0 + _REL) + margin
    return jnp.logical_not(skip), dc, margin


def active_tiles(c_new: jax.Array, cache: RoundCache,
                 tile_max: jax.Array) -> jax.Array:
    """Coarse level only of :func:`seed_gate` (historical entry point)."""
    return seed_gate(c_new, cache, tile_max)[0]


def seed_point_prune(min_d2: jax.Array, center_d: jax.Array, dc: jax.Array,
                     margin: jax.Array) -> jax.Array:
    """Per-point SEEDING prune mask for ONE tile: True where the min-update
    provably cannot change ``min_d2`` (so ``min(md, d2)`` would return ``md``
    bitwise — skipping the d2 evaluation is a value-noop). ``min_d2`` and
    ``center_d`` are the tile's (bn,) slices; ``dc``/``margin`` the tile's
    :func:`seed_gate` scalars. Shared verbatim by the pure-JAX gate model
    and the Pallas gated kernels."""
    lo = jnp.maximum(dc - center_d, 0.0)
    return lo * lo >= min_d2 * (1.0 + _REL) + margin


def seed_envelope(min_d2: jax.Array, weights) -> jax.Array:
    """The rejection sampler's stale proposal weights ``q_i = stale_min_d2 *
    w_i`` (see ``engine._seed_rejection_loop``).

    VALIDITY (the exactness precondition ``q_i >= p_i``): during seeding,
    centroids are only ever ADDED, so every point's min_d2 is monotonically
    NON-INCREASING across rounds — any stale copy of the array (and of the
    per-tile partials the tiled inverse-CDF draws from, which are sums of
    stale entries) dominates the current mass pointwise. No ball-radius or
    movement-decay argument is needed for domination itself; the ball
    machinery above gates what the *refresh* recomputes, and the refresh
    debt is exactly the pending-centroid block the loop carries in place of
    ``lb_debt``."""
    return min_d2 if weights is None else min_d2 * weights


def expand_mask(active: jax.Array, block_n: int, n: int) -> jax.Array:
    """Per-tile mask -> per-point mask (first n entries). Broadcast+reshape,
    NOT jnp.repeat: repeat lowers to a full-n cumsum, which would put an O(n)
    scan back into the jaxpr the tiled sampler is pinned to avoid."""
    n_tiles = active.shape[0]
    return jnp.broadcast_to(active[:, None],
                            (n_tiles, block_n)).reshape(-1)[:n]


def tile_reduce_max(x: jax.Array, block_n: int) -> jax.Array:
    """Per-tile max of a non-negative (n,) array (zero-padded tail) — the
    bound-state twin of ``sampling.tile_partials``."""
    n = x.shape[0]
    pad = (-n) % block_n
    xp = x if pad == 0 else jnp.pad(x, (0, pad))
    return xp.reshape(-1, block_n).max(axis=1)


def centroid_movement(new_c: jax.Array, old_c: jax.Array) -> jax.Array:
    """(k,) fp32 ``delta_j = ‖c_j^{t+1} − c_j^t‖`` — the assignment bound's
    per-centroid movement. Exactly zero iff the centroid did not move (a
    bitwise fixed point), which is the extra condition that makes skipping
    an assignment tile carry its ``min_d2`` exactly."""
    diff = new_c.astype(jnp.float32) - old_c.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


def tiles_per_super(n_tiles: int, tps: Optional[int] = None) -> int:
    """Static super-tile width: ~√n_tiles consecutive tiles share one
    accumulator slot (power of two, so ``super_id = t // tps`` is a shift).
    Caps the hierarchical accumulators at O(n_super·k·d) with
    n_super = ceil(n_tiles / tps) ≈ √n_tiles. Problems of ≤ 8 tiles keep
    the flat layout (tps = 1): there is no accumulator footprint to cap,
    and grouping would only coarsen the skip gate's alias granularity.

    ``tps`` overrides the heuristic (the autotuner's knob); it is clamped
    to [1, next_pow2(n_tiles)] and rounded to a power of two so
    ``super_id = t // tps`` stays a shift and the aliasing argument holds.
    """
    if tps is not None and tps > 0:
        cap = 1 << max(int(n_tiles - 1).bit_length(), 0) if n_tiles > 1 else 1
        t = 1 << (int(tps).bit_length() - 1)        # floor to power of two
        return max(1, min(t, cap))
    if n_tiles <= 8:
        return 1
    return 1 << ((int(n_tiles - 1).bit_length() + 1) // 2)


def n_supers(n_tiles: int, tps: Optional[int] = None) -> int:
    return -(-n_tiles // tiles_per_super(n_tiles, tps))


def expand_active_supers(active: jax.Array, tps: int) -> jax.Array:
    """Expand a per-tile active mask to whole super-tiles (floored at one
    active super). The hierarchical accumulators alias at SUPER granularity:
    a super's sums/counts block is carried only when ALL its tiles skip, so
    any active tile force-activates its whole super — a value-noop for the
    individually-skippable tiles (skipping is exact), whose points the
    per-point gate then prunes. The floor mirrors ``compact_ids``' write-back
    guard one level up: the one force-computed super keeps every visited
    accumulator block fully written."""
    n_tiles = active.shape[0]
    pad = (-n_tiles) % tps
    sup = jnp.pad(active, (0, pad)).reshape(-1, tps).any(axis=1)
    sup = sup.at[0].set(sup[0] | jnp.logical_not(jnp.any(sup)))
    return jnp.broadcast_to(sup[:, None],
                            (sup.shape[0], tps)).reshape(-1)[:n_tiles]


def super_any(active: jax.Array, tps: int) -> jax.Array:
    """(n_super,) bool — True where ANY tile of the super-tile is active
    (i.e. the super's accumulator block was rewritten this round)."""
    pad = (-active.shape[0]) % tps
    return jnp.pad(active, (0, pad)).reshape(-1, tps).any(axis=1)


def super_reduce(tile_arr: jax.Array, tps: int) -> jax.Array:
    """Reduce a per-tile array over each super-tile's tiles (leading axis
    n_tiles -> n_super). Zero-padding the ragged last super adds exact 0.0s,
    so the tree matches the kernel's sequential accumulation bitwise-safely
    for the pure-JAX model's own gated-vs-ungated comparisons."""
    n_tiles = tile_arr.shape[0]
    pad = (-n_tiles) % tps
    if pad:
        tile_arr = jnp.pad(tile_arr,
                           ((0, pad),) + ((0, 0),) * (tile_arr.ndim - 1))
    return tile_arr.reshape((-1, tps) + tile_arr.shape[1:]).sum(axis=1)


def assign_active_tiles(delta: jax.Array, centroids: jax.Array,
                        state: BoundState, cache: RoundCache,
                        tps: Optional[int] = None) -> jax.Array:
    """(n_tiles,) bool — True where an ASSIGNMENT tile might change labels.

    Tile t is skipped only when BOTH hold:

    * ``tile_gap_t >= delta_max`` (with the conservative fp32 margin): by
      the movement bound no point's runner-up can overtake its assigned
      centroid, so no label in the tile can change; and
    * every cluster the tile's SUPER-tile's carried counts mark occupied has
      ``delta_j == 0``: the assigned centroids are bitwise where they were
      when the tile last computed, so the carried ``min_d2``/partial/sums
      are bitwise what a recompute against the new centroids would produce
      (the matmul-form d2 of row j is elementwise in c_j). The occupancy is
      tracked per super-tile (the hierarchical accumulators' granularity) —
      coarser than the true per-tile occupancy, so the check is
      conservative: it can only keep a tile active, never skip one whose
      own centroid moved.

    The fp32 slack mirrors :func:`seed_gate`: the gap was computed from
    matmul-form d2 whose cancellation error is ABSOLUTE in the operand
    magnitude, and the sqrt step can turn that into ~sqrt(eps)·magnitude of
    distance error near zero, so the margin scales ``_ABS_GAP`` by the
    tile's distance-unit magnitude (never skips a tile exact arithmetic
    would keep — rounding only prunes less)."""
    n_tiles = state.partials.shape[0]
    tps = tiles_per_super(n_tiles, tps)
    dmax = jnp.max(delta)
    occupied = state.tile_counts > 0.0                      # (n_super, k)
    moved_sup = jnp.any(occupied & (delta[None, :] > 0.0), axis=1)
    moved = moved_sup[jnp.arange(n_tiles, dtype=jnp.int32) // tps]
    skip = jnp.logical_and(
        state.tile_gap >= dmax * (1.0 + _REL)
        + _ABS_GAP * _distance_scale(centroids, cache),
        jnp.logical_not(moved))
    return jnp.logical_not(skip)


def _distance_scale(centroids: jax.Array, cache: RoundCache) -> jax.Array:
    """(n_tiles,) distance-unit operand magnitude of each tile's d2 math —
    the scale both assignment-side absolute slacks multiply."""
    c = centroids.astype(jnp.float32)
    cmax = jnp.sqrt(jnp.max(jnp.sum(c * c, axis=-1)))
    return jnp.sqrt(jnp.sum(cache.centers * cache.centers, axis=1)) \
        + cache.radii + cmax


def assign_point_scalars(delta: jax.Array, centroids: jax.Array,
                         state: BoundState, cache: RoundCache
                         ) -> tuple[jax.Array, jax.Array]:
    """The two per-tile scalars the fine-level ASSIGNMENT gate streams:

    * ``thresh`` (n_tiles,) — prune threshold with the tile's lazy
      ``lb_debt`` folded in: point i of tile t short-circuits iff its own
      centroid is bitwise unmoved and ``point_lb[i] − sqrt(min_d2[i]) >=
      thresh_t`` (i.e. the DEBT-CORRECTED lb clears the movement bound with
      the conservative fp32 margin of :func:`assign_active_tiles`).
    * ``absorb`` (n_tiles,) — ``lb_debt_t + delta_max``: what a computed
      tile subtracts from the stored ``point_lb`` of its pruned points, so
      the stored value is exact-absolute again (debt resets to zero).
    """
    dmax = jnp.max(delta)
    thresh = (dmax * (1.0 + _REL)
              + _ABS_GAP * _distance_scale(centroids, cache)
              + state.lb_debt)
    return thresh, state.lb_debt + dmax


def assign_point_prune(prev_a: jax.Array, prev_md: jax.Array,
                       prev_lb: jax.Array, delta: jax.Array,
                       thresh: jax.Array, valid: jax.Array) -> jax.Array:
    """Per-point ASSIGNMENT prune mask for ONE tile (bn,): True where the
    point's label AND its exact ``min_d2`` provably cannot change, so the
    k-way distance recomputation short-circuits to the carried values —
    bitwise what a fresh compute would produce. Shared verbatim by the
    pure-JAX model and the Pallas gated kernels (the one-hot contraction
    instead of a gather keeps it Mosaic-friendly)."""
    k = delta.shape[0]
    onehot = (prev_a[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    own_delta = jnp.sum(jnp.where(onehot, delta[None, :], 0.0), axis=1)
    ub = jnp.sqrt(prev_md)
    return valid & (own_delta == 0.0) & (prev_lb - ub >= thresh)


def decay_gap(gap: jax.Array, active: jax.Array, fresh_gap: jax.Array,
              delta_max: jax.Array) -> jax.Array:
    """Next iteration's carried gap: fresh for computed tiles, carried-minus-
    movement for skipped ones (each step's ``max_j delta_j`` shrinks every
    stale margin, so a gap refreshed at iteration r stays a valid lower
    bound after any number of consecutive skips)."""
    return jnp.where(active, fresh_gap, gap - delta_max)


def ivf_gate_skip(dc: jax.Array, radius: jax.Array, center_norm: jax.Array,
                  q_norm: jax.Array, tau: jax.Array) -> jax.Array:
    """The IVF scan's per-tile kth-distance gate: True when tile t provably
    cannot beat the carried kth-best distance ``tau``.

    ``dc = d(q, center_t)``: by the triangle inequality every row x of the
    tile has ``d(q, x) >= dc - r_t``, so when ``(max(dc - r_t, 0))^2 >= tau``
    no candidate in the tile can enter the top-k. The fp32 slack mirrors
    :func:`seed_gate` — the scan evaluates candidate d2 in the matmul form,
    whose cancellation error is ABSOLUTE in the operand magnitude
    ``(||center|| + r + ||q||)^2`` — so a tile is only skipped when the
    kernel's OWN fp32 d2 values provably all exceed ``tau`` STRICTLY.
    Strictness matters for the bitwise value-noop: the blocked top-k merge
    orders by ``(d2, row)`` lexicographically, so a skipped candidate with
    ``d2 == tau`` but a smaller row id could otherwise displace the
    incumbent kth entry. With the positive margin, skipping implies
    ``d2 > tau`` for every row — gated and ungated scans return bitwise
    identical top-k (tested). ``tau = +inf`` (top-k not yet full) never
    skips. Shared verbatim by the Pallas scan kernels and the pure-jnp
    model."""
    lo = jnp.maximum(dc - radius, 0.0)
    margin = _ABS * (center_norm + radius + jnp.sqrt(q_norm)) ** 2
    return lo * lo >= tau * (1.0 + _REL) + margin


def compact_ids(active: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Compaction for the scalar-prefetched index map: returns
    ``(ids_clamped (n_tiles,) int32, n_active () int32)``.

    ``ids_clamped[i]`` is the i-th active tile id for ``i < n_active`` and the
    LAST active tile id after that, so the trailing grid steps of the gated
    kernel revisit an already-resident block (no extra HBM fetch) and are
    compute-gated off by ``i < n_active``. Stable argsort keeps active tiles
    in ascending order, preserving the pipeline's sequential-stream access
    pattern over the survivors.

    ``n_active`` is floored at 1 even when every tile clears the bound:
    grid step 0 then recomputes one skippable tile, which is a value-noop
    (skipping is exact) but guarantees every VISITED output block gets
    written — a compiled-Mosaic output block is write-only VMEM, so a
    visited-but-never-written block would flush garbage over the aliased
    buffer. Unvisited blocks are safe: the alias means their HBM contents
    are the donated inputs, untouched.
    """
    n_tiles = active.shape[0]
    order = jnp.argsort(jnp.logical_not(active), stable=True).astype(jnp.int32)
    n_active = jnp.maximum(jnp.sum(active), 1).astype(jnp.int32)
    clamp = jnp.minimum(jnp.arange(n_tiles, dtype=jnp.int32), n_active - 1)
    return order[clamp], n_active
