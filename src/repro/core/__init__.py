"""repro.core — the paper's contribution: parallel k-means++ seeding (+ Lloyd
clustering, k-means|| baseline, distributed shard_map versions), all routed
through the backend-dispatched ClusterEngine in ``repro.core.engine``."""
from repro.core.engine import (Backend, ClusterEngine, FusedBackend,
                               KmeansppResult, LloydResult, MeshBackend,
                               PallasBackend, ReferenceBackend, make_backend,
                               pairwise_d2, point_d2)
from repro.core.kmeanspp import kmeanspp, random_init
from repro.core.lloyd import assign, kmeans, lloyd, update
from repro.core.kmeans_parallel import kmeans_parallel_init
from repro.core.distributed import (dist_kmeans, dist_kmeanspp, dist_lloyd,
                                    dist_gumbel_choice, mesh_engine, ring_psum,
                                    take_global)
from repro.core import quality, sampling
from repro.core.guards import (CheckpointError, ClusteringError,
                               CorruptedStateError, InvalidInputError,
                               KernelFailureError, PipelineError)

__all__ = [
    "Backend", "ClusterEngine", "FusedBackend", "KmeansppResult",
    "LloydResult", "MeshBackend", "PallasBackend", "ReferenceBackend",
    "make_backend", "kmeanspp", "kmeans", "lloyd", "assign", "update",
    "pairwise_d2", "point_d2", "random_init", "kmeans_parallel_init",
    "dist_kmeans", "dist_kmeanspp", "dist_lloyd", "dist_gumbel_choice",
    "mesh_engine", "ring_psum", "take_global", "quality", "sampling",
    "ClusteringError", "InvalidInputError", "CorruptedStateError",
    "PipelineError", "KernelFailureError", "CheckpointError",
]
