"""Weighted categorical sampling primitives for k-means++ seeding.

Three exact methods:
  * inverse-CDF (`cdf`) — the classic serial method (cumsum + searchsorted).
    Used to prove serial == parallel seed selection under a matched PRNG key.
  * Gumbel-max (`gumbel`) — argmax(log w + Gumbel noise). Embarrassingly
    parallel, no prefix sum, and composes across shards with a tiny all-gather:
    the basis of the distributed seeding in `repro.core.distributed`.
  * two-level tiled (`tiled`) — inverse-CDF over per-tile partial sums (the
    seeding kernel's thrust::reduce partials), then inverse-CDF inside only
    the chosen tile. Reads O(n_tiles + block_n) elements instead of O(n) per
    draw while sampling the SAME distribution: the level-1 residual
    r - tile_cdf[t-1] is, conditional on tile t, uniform on [0, partials[t]),
    so one uniform drives both levels exactly.

Degenerate weights (all-zero — duplicate-point datasets after the first seed —
or NaN/inf totals) fall back to a uniform draw over all indices instead of
silently returning a clipped index; the guard is shared by all three methods
(`safe_log` maps the zero weights the cdf path skips to -inf for the Gumbel
paths, so the two representations agree on which indices are sampleable).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -jnp.inf


def categorical(key: jax.Array, weights: jax.Array, *,
                total: Optional[jax.Array] = None, method: str = "cdf") -> jax.Array:
    if method == "cdf":
        return categorical_cdf(key, weights, total=total)
    if method == "gumbel":
        idx = gumbel_max(key, safe_log(weights))
        # all-zero weights make every score -inf (argmax pins to 0); the max
        # weight is the cheapest positive-mass witness for the shared guard
        return _guarded(key, idx, jnp.max(weights), weights.shape[0])
    raise ValueError(f"unknown sampler {method!r}")


def safe_log(w: jax.Array) -> jax.Array:
    """log(w) with log(0) -> -inf (zero-weight entries can never be sampled)."""
    return jnp.where(w > 0, jnp.log(jnp.where(w > 0, w, 1.0)), _NEG_INF)


def gumbel_max(key: jax.Array, log_weights: jax.Array) -> jax.Array:
    g = jax.random.gumbel(key, log_weights.shape, log_weights.dtype)
    return jnp.argmax(log_weights + g).astype(jnp.int32)


def gumbel_topk(key: jax.Array, log_weights: jax.Array, k: int):
    """Exact weighted sampling *without replacement* of k indices (Gumbel top-k)."""
    n = log_weights.shape[0]
    if k > n:
        raise ValueError(f"gumbel_topk needs k <= n, got k={k}, n={n}")
    g = jax.random.gumbel(key, log_weights.shape, log_weights.dtype)
    scores = log_weights + g
    _, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32)


def gumbel_max_local(key: jax.Array, log_weights: jax.Array):
    """Per-shard half of a distributed Gumbel-max: returns (best_score, best_idx).

    Combining rule: the global argmax of (score, idx) pairs over shards is an
    exact sample from the global categorical — used inside shard_map with a
    small all_gather (see repro.core.distributed.dist_gumbel_choice).
    """
    g = jax.random.gumbel(key, log_weights.shape, log_weights.dtype)
    scores = log_weights + g
    best = jnp.argmax(scores).astype(jnp.int32)
    return scores[best], best


# ---------------------------------------------------------------------------
# inverse-CDF: global and two-level tiled
# ---------------------------------------------------------------------------


def index_from_uniform(u: jax.Array, weights: jax.Array, *,
                       total: Optional[jax.Array] = None) -> jax.Array:
    """Deterministic half of inverse-CDF sampling: map u in [0, 1) to the idx
    with cumsum[idx-1] <= u * total < cumsum[idx]. Exposed separately so the
    tiled sampler's distribution-exactness can be tested on a dense u-grid."""
    cdf = jnp.cumsum(weights)
    tot = cdf[-1] if total is None else total
    r = u * tot
    idx = jnp.searchsorted(cdf, r, side="right")
    return jnp.clip(idx, 0, weights.shape[0] - 1).astype(jnp.int32)


def tile_window(weights: jax.Array, t: jax.Array, block_n: int) -> jax.Array:
    """The (block_n,) weight slice of tile t (zero-padded tail tile) — the
    only O(block_n) read a two-level draw performs. Shared by the local tiled
    sampler and the distributed `collectives.dist_tiled_choice`."""
    n = weights.shape[0]
    pad = (-n) % block_n
    wpad = weights if pad == 0 else jnp.pad(weights, (0, pad))
    return jax.lax.dynamic_slice(wpad, (t * block_n,), (block_n,))


def tiled_index_from_uniform(u: jax.Array, weights: jax.Array,
                             partials: jax.Array, *, block_n: int) -> jax.Array:
    """Two-level inverse-CDF: tile t via the n_tiles partial sums, then the
    offset inside tile t via a (block_n,)-slice of `weights` — O(n/bn + bn)
    reads. `partials[t]` must equal sum(weights[t*bn:(t+1)*bn]) (up to fp
    association order); the level-2 residual reuses the SAME uniform, which
    conditional on tile t is uniform on the tile's mass, so the composite is
    an exact draw from weights/sum(weights)."""
    n = weights.shape[0]
    n_tiles = partials.shape[0]
    tcdf = jnp.cumsum(partials)
    r = u.astype(tcdf.dtype) * tcdf[-1]
    t = jnp.clip(jnp.searchsorted(tcdf, r, side="right"), 0, n_tiles - 1)
    r_local = r - jnp.where(t > 0, tcdf[jnp.maximum(t - 1, 0)], 0.0)

    tile = tile_window(weights, t, block_n)
    lcdf = jnp.cumsum(tile)
    li = jnp.clip(jnp.searchsorted(lcdf, r_local, side="right"),
                  0, block_n - 1)
    # fp-underflow guard: level 1 can land on a tile whose (block_n,) window
    # re-sums to zero/non-finite even though partials[t] > 0 (the partial came
    # from the kernel's on-chip tree, a different association order).
    # searchsorted over a degenerate lcdf pins to one clipped index; fall back
    # to a uniform offset within the tile instead, matching `categorical`'s
    # degenerate-weight discipline. Conditional on tile t the residual
    # r_local / partials[t] is uniform on [0, 1), so the fallback costs no
    # extra uniform.
    wtot = lcdf[block_n - 1]
    frac = jnp.clip(r_local / jnp.maximum(partials[t],
                                          jnp.finfo(tcdf.dtype).tiny),
                    0.0, 1.0)
    li_fb = jnp.minimum((frac * block_n).astype(jnp.int32), block_n - 1)
    li = jnp.where(jnp.isfinite(wtot) & (wtot > 0), li, li_fb)
    return jnp.minimum(t * block_n + li, n - 1).astype(jnp.int32)


def super_cdf(tcdf: jax.Array, tps: int) -> jax.Array:
    """(n_super,) coarse-level CDF for the super->tile->row draw: the flat
    tile CDF GATHERED at each super's last tile, NOT a re-summation of the
    partials. Gathering keeps every super boundary bitwise a flat-cdf prefix
    (``scdf[-1] == tcdf[-1]`` exactly), which is what makes the two-level
    search telescope to the identical tile the flat searchsorted would pick
    — the foundation of the `hier == tiled` bitwise pin."""
    n_tiles = tcdf.shape[0]
    n_super = -(-n_tiles // tps)
    ends = jnp.minimum((jnp.arange(n_super) + 1) * tps - 1, n_tiles - 1)
    return tcdf[ends]


def hier_index_from_uniform(u: jax.Array, weights: jax.Array,
                            partials: jax.Array, tcdf: jax.Array,
                            scdf: jax.Array, *, block_n: int, tps: int,
                            cap: Optional[jax.Array] = None,
                            tight: Optional[jax.Array] = None,
                            w: Optional[jax.Array] = None) -> jax.Array:
    """Coarse-to-fine three-level inverse-CDF: super-tile s via the
    (n_super,) gathered boundaries, tile t via only the chosen super's
    (tps,) slice of the flat tile CDF, then the row inside tile t —
    O(n_super + tps + block_n) reads instead of O(n_tiles + block_n).

    Exactness/bitwise contract: ``scdf`` must come from `super_cdf(tcdf,
    tps)` (gathered boundaries). searchsorted-right over the boundaries
    returns the first super whose last tile's prefix exceeds r, which is
    exactly ``t_flat // tps``; the within-super search over the tps-wide
    tcdf window (inf-padded past the last tile) with the ABSOLUTE r then
    recovers ``t_flat`` itself, and the identical residual + row-level code
    returns the flat draw's index BITWISE.

    ``cap``/``tight`` (optional, from the movement-tightened envelope)
    switch the row level of tiles where the per-tile Raff cap beats the
    stale partial to a capped-window draw: rows are drawn ∝
    ``min(weights_i, cap_t * w_i)`` with the residual rescaled through the
    tightened tile mass ``partials[t]`` (conditional on t the residual is
    uniform on [0, partials[t]), so the rescale costs no extra uniform).
    Untightened tiles (``tight[t]`` False) run the flat row-level code
    bitwise — so with no tightening active the whole draw pins `tiled`.

    Super-level degenerate guard (the tile level's fp-underflow discipline,
    lifted one level): an all-zero or NaN coarse mass (``scdf[-1]``) would
    let searchsorted pin to one clipped super; instead the single uniform
    telescopes into a uniform super -> tile -> row fallback so no NaN ever
    steers the draw. The healthy path is bitwise unchanged."""
    n = weights.shape[0]
    n_tiles = partials.shape[0]
    n_super = scdf.shape[0]
    stot = scdf[n_super - 1]  # == tcdf[-1] bitwise (gathered boundary)
    r = u.astype(tcdf.dtype) * stot
    s = jnp.clip(jnp.searchsorted(scdf, r, side="right"), 0, n_super - 1)
    # within-super tile search: tps-wide window of the FLAT tcdf, inf-padded
    # so tail pads can never win a right-searchsorted against a finite r
    tpad = jnp.concatenate([tcdf, jnp.full((tps,), jnp.inf, tcdf.dtype)])
    twin = jax.lax.dynamic_slice(tpad, (s * tps,), (tps,))
    t = jnp.clip(s * tps + jnp.searchsorted(twin, r, side="right"),
                 0, n_tiles - 1)
    r_local = r - jnp.where(t > 0, tcdf[jnp.maximum(t - 1, 0)], 0.0)

    win = tile_window(weights, t, block_n)
    tiny = jnp.finfo(tcdf.dtype).tiny
    ph_t = partials[t]
    if cap is None:
        use, r2, tight_t = win, r_local, jnp.zeros((), bool)
    else:
        cw = cap[t] if w is None else cap[t] * tile_window(w, t, block_n)
        # where-form, not minimum(): inf * 0 pads give NaN and NaN must
        # lose the comparison, keeping the stale window untouched
        cwin = jnp.where(cw < win, cw, win)
        tight_t = tight[t]
        use = jnp.where(tight_t, cwin, win)
        lsum = jnp.cumsum(use)[block_n - 1]
        r2 = jnp.where(tight_t,
                       (r_local / jnp.maximum(ph_t, tiny)) * lsum, r_local)
    lcdf = jnp.cumsum(use)
    li = jnp.clip(jnp.searchsorted(lcdf, r2, side="right"), 0, block_n - 1)
    # the tile level's fp-underflow guard, unchanged (see
    # tiled_index_from_uniform): degenerate window -> uniform offset
    wtot = lcdf[block_n - 1]
    frac = jnp.clip(r_local / jnp.maximum(ph_t, tiny), 0.0, 1.0)
    li_fb = jnp.minimum((frac * block_n).astype(jnp.int32), block_n - 1)
    li = jnp.where(jnp.isfinite(wtot) & (wtot > 0), li, li_fb)
    idx = jnp.minimum(t * block_n + li, n - 1).astype(jnp.int32)

    # super-level degenerate guard: telescope the one uniform through
    # uniform-over-supers -> tiles -> rows (satellite of ISSUE 9)
    us = u.astype(tcdf.dtype) * n_super
    s_fb = jnp.minimum(us.astype(jnp.int32), n_super - 1)
    ut = (us - s_fb) * tps
    t_fb = jnp.minimum(s_fb * tps + ut.astype(jnp.int32), n_tiles - 1)
    ur = (ut - jnp.floor(ut)) * block_n
    idx_fb = jnp.minimum(t_fb * block_n +
                         jnp.minimum(ur.astype(jnp.int32), block_n - 1),
                         n - 1).astype(jnp.int32)
    sok = jnp.isfinite(stot) & (stot > 0)
    return jnp.where(sok, idx, idx_fb)


def categorical_cdf(key: jax.Array, weights: jax.Array, *,
                    total: Optional[jax.Array] = None) -> jax.Array:
    """Inverse-CDF sampling: idx such that cumsum[idx-1] <= r < cumsum[idx].
    All-zero / non-finite weight mass falls back to a uniform index."""
    cdf = jnp.cumsum(weights)
    tot = cdf[-1] if total is None else total
    u = jax.random.uniform(key, (), weights.dtype)
    idx = jnp.clip(jnp.searchsorted(cdf, u * tot, side="right"),
                   0, weights.shape[0] - 1)
    return _guarded(key, idx, tot, weights.shape[0])


def categorical_tiled(key: jax.Array, weights: jax.Array,
                      partials: jax.Array, *, block_n: int) -> jax.Array:
    """Two-level tiled draw (see `tiled_index_from_uniform`). The degenerate
    guard reads only the n_tiles partials, keeping the whole draw sub-O(n)."""
    u = jax.random.uniform(key, (), weights.dtype)
    idx = tiled_index_from_uniform(u, weights, partials, block_n=block_n)
    return _guarded(key, idx, jnp.sum(partials), weights.shape[0])


def categorical_hier(key: jax.Array, weights: jax.Array,
                     partials: jax.Array, *, block_n: int,
                     tps: int) -> jax.Array:
    """Coarse-to-fine guarded draw (see `hier_index_from_uniform`): the
    super level treats each super-tile as a coreset point whose weight is
    its gathered partial mass (Capó-style), and only the chosen super is
    refined tile -> row. Same uniform derivation and degenerate discipline
    as `categorical_tiled`, so healthy draws are bitwise identical to it —
    just O(n_super + tps + block_n) reads instead of O(n_tiles + block_n)."""
    u = jax.random.uniform(key, (), weights.dtype)
    tcdf = jnp.cumsum(partials)
    scdf = super_cdf(tcdf, tps)
    idx = hier_index_from_uniform(u, weights, partials, tcdf, scdf,
                                  block_n=block_n, tps=tps)
    return _guarded(key, idx, jnp.sum(partials), weights.shape[0])


# ---------------------------------------------------------------------------
# rejection sampling from a stale dominating envelope
# ---------------------------------------------------------------------------

_ACCEPT_SALT = 0xACC  # fold_in salt for the accept uniform (disjoint from
#                       _guarded's 0x0DD so the two streams never collide)


def rejection_sample(key: jax.Array, propose_fn, pq_fn, *,
                     max_attempts: int,
                     valid: Optional[jax.Array] = None):
    """Truncated rejection draw from a target p via a dominating envelope q.

    ``propose_fn(kj) -> idx`` draws an index from the envelope (q_i / Q) —
    locally the two-level tiled inverse-CDF over STALE weights, on a mesh the
    distributed tiled choice. ``pq_fn(idx) -> (p, q)`` returns the exact
    current weight of the drawn row and its envelope weight; exactness needs
    ``0 <= p_i <= q_i`` (k-means++ seeding guarantees it: centroids are only
    ever added, so a stale min_d2 dominates the current one pointwise).

    Attempt j accepts iff ``u2 * q < p`` with u2 ~ U[0, 1): probability
    p_i/q_i, making each attempt an exact draw from p conditional on
    acceptance. Attempt 0 uses ``key`` VERBATIM (so a fresh envelope with
    p == q reproduces ``categorical_tiled(key, ...)`` bitwise — the shared
    uniform stream the parity tests pin); attempt j > 0 uses
    ``fold_in(key, j)``. Returns ``(idx, accepted, attempts)``; when all
    ``max_attempts`` proposals reject the caller MUST fall back to an exact
    full draw with an INDEPENDENT key — the truncated-attempts + exact-
    fallback mixture is still exactly p (the attempts are i.i.d., so the
    geometric telescoping is unchanged by truncation).

    Degenerate envelopes (zero/non-finite mass) make every attempt reject
    (p = q = 0 fails the strict test; non-finite q poisons it), routing to
    the fallback draw — whose own `_guarded` uniform fallback then matches
    `categorical_tiled`'s degenerate-weight discipline.

    ``valid`` (optional traced bool) is the fp-invalid-envelope guard: a
    corrupted envelope (negative or NaN stale partials) can make the
    dominance precondition ``p <= q`` FALSE, in which case an accepted draw
    would be silently biased — rejection-until-fallback is not a safe
    default there. When ``valid`` is False the proposal loop is skipped
    outright (``attempts == 0``, ``accepted`` False) so the caller routes
    straight to its exact fallback path. When ``valid`` is True (or None)
    the loop executes identically to the unguarded form — same attempt
    keys, same uniforms — keeping the healthy path bitwise unchanged.
    """
    def attempt_key(j):
        return jax.lax.cond(j == 0, lambda k: k,
                            lambda k: jax.random.fold_in(k, j), key)

    env_ok = (jnp.ones((), bool) if valid is None
              else jnp.asarray(valid, bool))

    def cond(carry):
        j, _, ok = carry
        return jnp.logical_not(ok) & (j < max_attempts) & env_ok

    def body(carry):
        j, _, _ = carry
        kj = attempt_key(j)
        idx = propose_fn(kj)
        p, q = pq_fn(idx)
        u2 = jax.random.uniform(jax.random.fold_in(kj, _ACCEPT_SALT), (),
                                p.dtype)
        return j + 1, idx.astype(jnp.int32), u2 * q < p

    attempts, idx, ok = jax.lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                     jnp.zeros((), bool)))
    return idx, ok, attempts


def _guarded(key: jax.Array, idx: jax.Array, total: jax.Array,
             n: int) -> jax.Array:
    ok = jnp.isfinite(total) & (total > 0)
    rand = jax.random.randint(jax.random.fold_in(key, 0x0DD), (),
                              0, n, dtype=jnp.int32)
    return jnp.where(ok, idx.astype(jnp.int32), rand)


def tile_partials(x: jax.Array, block_n: int) -> jax.Array:
    """Per-tile sums of a (n,) array with tile height block_n (zero-padded
    tail) — the reference/fused backends' analogue of the Pallas kernel's
    on-chip per-tile partial accumulator."""
    n = x.shape[0]
    pad = (-n) % block_n
    xp = x if pad == 0 else jnp.pad(x, (0, pad))
    return xp.reshape(-1, block_n).sum(axis=1)
