"""Weighted categorical sampling primitives for k-means++ seeding.

Two exact methods:
  * inverse-CDF (`cdf`) — the classic serial method (cumsum + searchsorted).
    Used to prove serial == parallel seed selection under a matched PRNG key.
  * Gumbel-max (`gumbel`) — argmax(log w + Gumbel noise). Embarrassingly
    parallel, no prefix sum, and composes across shards with a tiny all-gather:
    the basis of the distributed seeding in `repro.core.distributed`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -jnp.inf


def categorical(key: jax.Array, weights: jax.Array, *,
                total: Optional[jax.Array] = None, method: str = "cdf") -> jax.Array:
    if method == "cdf":
        return categorical_cdf(key, weights, total=total)
    if method == "gumbel":
        return gumbel_max(key, safe_log(weights))
    raise ValueError(f"unknown sampler {method!r}")


def safe_log(w: jax.Array) -> jax.Array:
    """log(w) with log(0) -> -inf (zero-weight entries can never be sampled)."""
    return jnp.where(w > 0, jnp.log(jnp.where(w > 0, w, 1.0)), _NEG_INF)


def categorical_cdf(key: jax.Array, weights: jax.Array, *,
                    total: Optional[jax.Array] = None) -> jax.Array:
    """Inverse-CDF sampling: idx such that cumsum[idx-1] <= r < cumsum[idx]."""
    cdf = jnp.cumsum(weights)
    tot = cdf[-1] if total is None else total
    r = jax.random.uniform(key, (), weights.dtype) * tot
    idx = jnp.searchsorted(cdf, r, side="right")
    return jnp.clip(idx, 0, weights.shape[0] - 1).astype(jnp.int32)


def gumbel_max(key: jax.Array, log_weights: jax.Array) -> jax.Array:
    g = jax.random.gumbel(key, log_weights.shape, log_weights.dtype)
    return jnp.argmax(log_weights + g).astype(jnp.int32)


def gumbel_topk(key: jax.Array, log_weights: jax.Array, k: int):
    """Exact weighted sampling *without replacement* of k indices (Gumbel top-k)."""
    g = jax.random.gumbel(key, log_weights.shape, log_weights.dtype)
    scores = log_weights + g
    _, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32)


def gumbel_max_local(key: jax.Array, log_weights: jax.Array):
    """Per-shard half of a distributed Gumbel-max: returns (best_score, best_idx).

    Combining rule: the global argmax of (score, idx) pairs over shards is an
    exact sample from the global categorical — used inside shard_map with a
    small all_gather (see repro.core.distributed.dist_gumbel_choice).
    """
    g = jax.random.gumbel(key, log_weights.shape, log_weights.dtype)
    scores = log_weights + g
    best = jnp.argmax(scores).astype(jnp.int32)
    return scores[best], best
