"""Mesh collective primitives shared by the distributed clustering paths.

These are the building blocks the ``mesh`` ClusterEngine backend (see
``repro.core.engine``) composes into pod-scale seeding/Lloyd rounds: points are
sharded over the data axes, centroids replicated, and every round costs
O(devices) scalars + O(d) for the winner broadcast — independent of N.

Extracted from ``repro.core.distributed`` so the engine can depend on them
without a circular import; ``distributed`` re-exports for back-compat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import pvary, shard_map  # noqa: F401  (re-exported)
from repro.core import sampling


def axis_size(axes):
    return jax.lax.psum(1, axes)


def axis_index(axes) -> jax.Array:
    """Linearized index over (possibly multiple) mesh axes."""
    if isinstance(axes, str):
        return jax.lax.axis_index(axes)
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def shard_argmax(score: jax.Array, global_idx: jax.Array, axes) -> jax.Array:
    """Global index whose shard-local score wins the pmax, pmin tie-broken —
    the two O(1)-byte collectives every distributed sampler combine uses."""
    best = jax.lax.pmax(score, axes)
    cand = jnp.where(score == best, global_idx, jnp.iinfo(jnp.int32).max)
    return jax.lax.pmin(cand, axes)


def dist_gumbel_choice(key: jax.Array, log_w: jax.Array, axes) -> jax.Array:
    """Exact distributed categorical sample via Gumbel-max.

    Each shard computes its local (best_score, best_local_idx); a pmax over the
    scores plus a pmin tie-break over indices picks the global winner with two
    O(1)-byte collectives (no gather of D^2 to any single device). Returns the
    GLOBAL index (shard_offset + local idx), replicated on every shard.
    """
    me = axis_index(axes)
    n_local = log_w.shape[0]
    shard_key = jax.random.fold_in(key, me)
    score, local_idx = sampling.gumbel_max_local(shard_key, log_w)
    return shard_argmax(score, me * n_local + local_idx, axes)


def dist_tiled_choice(key: jax.Array, weights: jax.Array,
                      partials: jax.Array, block_n: int, axes) -> jax.Array:
    """Exact distributed categorical sample from per-tile partial sums.

    Three-level hierarchical composition of the seeding kernel's partials
    with the distributed Gumbel-max:

      1. tile:  each shard draws Gumbel scores over log(partials) — the max
         over tiles is Gumbel(log local_total) by max-stability, and the
         argmax picks a tile with prob partials[t]/local_total;
      2. point: the winning tile's (block_n,) weight slice is sampled by
         inverse-CDF — prob w_i/partials[t];
      3. shard: pmax of the per-shard max scores picks a shard with prob
         local_total/global_total (the same combining rule as
         `dist_gumbel_choice`), with a pmin tie-break on indices.

    The product telescopes to w_i/global_total — an exact global draw that
    reads O(n_local/block_n + block_n) elements per shard after the round
    kernel instead of O(n_local). Returns the GLOBAL index, replicated."""
    me = axis_index(axes)
    n_local = weights.shape[0]
    shard_key = jax.random.fold_in(key, me)
    kt, kp = jax.random.split(shard_key)

    score, t = sampling.gumbel_max_local(kt, sampling.safe_log(partials))

    within = sampling.categorical_cdf(kp, sampling.tile_window(weights, t,
                                                               block_n))
    local_idx = jnp.minimum(t * block_n + within, n_local - 1)
    return shard_argmax(score, me * n_local + local_idx, axes)


def dist_hier_choice(key: jax.Array, weights: jax.Array,
                     partials: jax.Array, block_n: int, tps: int, axes,
                     cap: jax.Array = None, tight: jax.Array = None
                     ) -> jax.Array:
    """Coarse-to-fine distributed categorical sample: the four-level
    composition super-tile -> tile -> point -> shard.

      1. super: each shard draws Gumbel scores over log(super masses) —
         the gathered-boundary differences of its tile CDF (see
         `sampling.super_cdf`) — picking super s with prob mass_s/local_total
         and carrying a Gumbel(log local_total) max score by max-stability;
      2. tile:  inverse-CDF over only the chosen super's (tps,) partials
         slice — prob partials[t]/mass_s;
      3. point: inverse-CDF over the winning tile's (block_n,) weight slice,
         switched to the capped window ``min(weights, cap_t)`` where the
         per-tile Raff cap tightens the stale envelope (``tight[t]``);
      4. shard: the same pmax + pmin-tie-break combine as
         `dist_gumbel_choice` — max-stability makes the per-shard max score
         Gumbel(log local_total) regardless of the partition granularity,
         so the combine is unchanged from the flat tiled draw.

    Reads O(n_local/(block_n*tps) + tps + block_n) elements per shard
    post-kernel instead of the flat draw's O(n_local/block_n + block_n).
    Returns the GLOBAL index, replicated. NOTE: a different key schedule
    than `dist_tiled_choice` (three splits, not two) — callers that need
    the refresh_block=1 bitwise pin route fresh-envelope rounds through
    the flat draw instead (see engine._seed_mesh)."""
    me = axis_index(axes)
    n_local = weights.shape[0]
    n_tiles = partials.shape[0]
    shard_key = jax.random.fold_in(key, me)
    ks, kt, kp = jax.random.split(shard_key, 3)

    tcdf = jnp.cumsum(partials)
    scdf = sampling.super_cdf(tcdf, tps)
    sup = scdf - jnp.concatenate([jnp.zeros((1,), scdf.dtype), scdf[:-1]])
    score, s = sampling.gumbel_max_local(ks, sampling.safe_log(sup))

    ppad = jnp.concatenate([partials, jnp.zeros((tps,), partials.dtype)])
    pwin = jax.lax.dynamic_slice(ppad, (s * tps,), (tps,))
    t = jnp.minimum(s * tps + sampling.categorical_cdf(kt, pwin),
                    n_tiles - 1)

    win = sampling.tile_window(weights, t, block_n)
    if cap is not None:
        # where-form, not minimum(): inf-cap * zero-pad NaNs must lose
        cwin = jnp.where(cap[t] < win, cap[t], win)
        win = jnp.where(tight[t], cwin, win)
    within = sampling.categorical_cdf(kp, win)
    local_idx = jnp.minimum(t * block_n + within, n_local - 1)
    return shard_argmax(score, me * n_local + local_idx, axes)


def dist_gumbel_topl(key: jax.Array, log_w: jax.Array, l: int, axes):
    """Exact distributed Gumbel top-l: sample l indices WITHOUT replacement
    from the sharded categorical exp(log_w) — the k-means|| oversampling draw.

    Each shard takes its local top-l Gumbel scores (candidates for the global
    top-l must be a shard-local top-l), all-gathers the (l,) score/global-index
    pairs (O(l * n_shards) scalars, independent of N), and every shard reduces
    the l*S candidates to the same global top-l. Returns (global_idx (l,),
    scores (l,)), replicated on every shard."""
    me = axis_index(axes)
    n_local = log_w.shape[0]
    shard_key = jax.random.fold_in(key, me)
    g = log_w.astype(jnp.float32) + jax.random.gumbel(
        shard_key, log_w.shape, jnp.float32)
    score, local_idx = jax.lax.top_k(g, l)
    gidx = me * n_local + local_idx.astype(jnp.int32)
    all_scores = jax.lax.all_gather(score, axes, tiled=True)
    all_gidx = jax.lax.all_gather(gidx, axes, tiled=True)
    best, pos = jax.lax.top_k(all_scores, l)
    return all_gidx[pos], best


def take_global_rows(points_local: jax.Array, global_idx: jax.Array,
                     axes) -> jax.Array:
    """Vector form of `take_global`: fetch the (l,) rows `global_idx` of the
    axis-0-sharded array with a single (l, d) psum — each row contributed by
    its owning shard, zeros elsewhere."""
    me = axis_index(axes)
    n_local = points_local.shape[0]
    owner = global_idx // n_local
    local = jnp.clip(global_idx - me * n_local, 0, n_local - 1)
    rows = jnp.where((me == owner)[:, None], points_local[local],
                     jnp.zeros_like(points_local[0])[None, :])
    return jax.lax.psum(rows, axes)


def take_global(points_local: jax.Array, global_idx: jax.Array, axes) -> jax.Array:
    """Fetch the row `global_idx` of the sharded (axis-0) array: the owning shard
    contributes the row, everyone else zeros, and one psum broadcasts it."""
    me = axis_index(axes)
    n_local = points_local.shape[0]
    owner = global_idx // n_local
    local = jnp.clip(global_idx - me * n_local, 0, n_local - 1)
    row = jnp.where(me == owner, points_local[local],
                    jnp.zeros_like(points_local[0]))
    return jax.lax.psum(row, axes)


def ring_psum(x: jax.Array, axis: str) -> jax.Array:
    """Ring all-reduce built from ppermute — demonstrates the collective the
    compiler emits for psum and lets the k-means|| round overlap its candidate
    broadcast with local compute (each hop's add overlaps the next permute)."""
    n = jax.lax.psum(1, axis)
    if isinstance(n, jax.Array):  # abstract axis size — fall back
        return jax.lax.psum(x, axis)

    def body(i, acc_cur):
        acc, cur = acc_cur
        nxt = jax.lax.ppermute(
            cur, axis, [(j, (j + 1) % n) for j in range(n)])
        return acc + nxt, nxt

    acc, _ = jax.lax.fori_loop(0, n - 1, body, (x, x))
    return acc
