"""Distributed k-means++ / k-means over a device mesh (shard_map).

This lifts the paper's thread-per-point decomposition to pod scale: points are
sharded over the data axes of the mesh, centroids are replicated (the
constant-memory idea applied at mesh level), and each seeding round is

    local fused D^2 min-update  ->  local partial sum  ->  psum  ->
    distributed Gumbel-max sample  ->  psum-broadcast of the winning point

Per-round collective traffic is O(devices) scalars + O(d) for the winner
broadcast — independent of N, which is what makes this the 1000-node design.

The round logic itself now lives in ``repro.core.engine`` (MeshBackend wraps a
local compute backend with the psum collectives); this module keeps the
historical ``dist_*`` entry points and re-exports the collective helpers that
moved to ``repro.core.collectives``.
"""
from __future__ import annotations

from typing import Sequence, Union

import jax

from repro.core.collectives import (axis_index, axis_size,  # noqa: F401
                                    dist_gumbel_choice, dist_hier_choice,
                                    dist_tiled_choice, pvary, ring_psum,
                                    take_global)
from repro.core.engine import (ClusterEngine, KmeansppResult, LloydResult,
                               MeshBackend, make_backend)
from jax.sharding import Mesh

__all__ = ["dist_kmeanspp", "dist_lloyd", "dist_kmeans", "dist_gumbel_choice",
           "dist_tiled_choice", "dist_hier_choice",
           "take_global", "ring_psum", "mesh_engine"]


def mesh_engine(mesh: Mesh, axes: Union[str, Sequence[str]] = "data",
                variant: str = "fused") -> ClusterEngine:
    """ClusterEngine over a MeshBackend; `variant` picks the per-shard compute
    ('fused', 'pallas_constant', 'pallas_fused', ...)."""
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    return ClusterEngine(MeshBackend(mesh=mesh, axes=axes_t,
                                     local=make_backend(variant)))


def dist_kmeanspp(key: jax.Array, points: jax.Array, k: int, *, mesh: Mesh,
                  axes: Union[str, Sequence[str]] = "data",
                  variant: str = "fused") -> KmeansppResult:
    """Distributed k-means++ seeding. `points` sharded on axis 0 over `axes`."""
    return mesh_engine(mesh, axes, variant).seed(key, points, k)


def dist_lloyd(points: jax.Array, init_centroids: jax.Array, *, mesh: Mesh,
               axes: Union[str, Sequence[str]] = "data", max_iters: int = 50,
               tol: float = 1e-6) -> LloydResult:
    return mesh_engine(mesh, axes).fit(points, init_centroids,
                                       max_iters=max_iters, tol=tol)


def dist_kmeans(key: jax.Array, points: jax.Array, k: int, *, mesh: Mesh,
                axes: Union[str, Sequence[str]] = "data", variant: str = "fused",
                max_iters: int = 50) -> LloydResult:
    eng = mesh_engine(mesh, axes, variant)
    seeds = eng.seed(key, points, k)
    return eng.fit(points, seeds.centroids, max_iters=max_iters)
