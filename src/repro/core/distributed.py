"""Distributed k-means++ / k-means over a device mesh (shard_map).

This lifts the paper's thread-per-point decomposition to pod scale: points are
sharded over the data axes of the mesh, centroids are replicated (the
constant-memory idea applied at mesh level), and each seeding round is

    local fused D^2 min-update  ->  local partial sum  ->  psum  ->
    distributed Gumbel-max sample  ->  psum-broadcast of the winning point

Per-round collective traffic is O(devices) scalars + O(d) for the winner
broadcast — independent of N, which is what makes this the 1000-node design.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import sampling
from repro.core.kmeanspp import KmeansppResult, pairwise_d2, point_d2
from repro.core.lloyd import LloydResult


# ---------------------------------------------------------------------------
# collective helpers
# ---------------------------------------------------------------------------

def _axis_size(axes):
    return jax.lax.psum(1, axes)


def _pvary(x, axes):
    """Mark an array as device-varying over `axes` (JAX>=0.7 VMA tracking)."""
    return jax.lax.pcast(x, axes, to="varying")


def _axis_index(axes) -> jax.Array:
    """Linearized index over (possibly multiple) mesh axes."""
    if isinstance(axes, str):
        return jax.lax.axis_index(axes)
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def dist_gumbel_choice(key: jax.Array, log_w: jax.Array, axes) -> jax.Array:
    """Exact distributed categorical sample via Gumbel-max.

    Each shard computes its local (best_score, best_local_idx); a pmax over the
    scores plus a pmin tie-break over indices picks the global winner with two
    O(1)-byte collectives (no gather of D^2 to any single device). Returns the
    GLOBAL index (shard_offset + local idx), replicated on every shard.
    """
    me = _axis_index(axes)
    n_local = log_w.shape[0]
    shard_key = jax.random.fold_in(key, me)
    score, local_idx = sampling.gumbel_max_local(shard_key, log_w)
    global_idx = me * n_local + local_idx
    best = jax.lax.pmax(score, axes)
    cand = jnp.where(score == best, global_idx, jnp.iinfo(jnp.int32).max)
    return jax.lax.pmin(cand, axes)


def take_global(points_local: jax.Array, global_idx: jax.Array, axes) -> jax.Array:
    """Fetch the row `global_idx` of the sharded (axis-0) array: the owning shard
    contributes the row, everyone else zeros, and one psum broadcasts it."""
    me = _axis_index(axes)
    n_local = points_local.shape[0]
    owner = global_idx // n_local
    local = jnp.clip(global_idx - me * n_local, 0, n_local - 1)
    row = jnp.where(me == owner, points_local[local],
                    jnp.zeros_like(points_local[0]))
    return jax.lax.psum(row, axes)


def ring_psum(x: jax.Array, axis: str) -> jax.Array:
    """Ring all-reduce built from ppermute — demonstrates the collective the
    compiler emits for psum and lets the k-means|| round overlap its candidate
    broadcast with local compute (each hop's add overlaps the next permute)."""
    n = jax.lax.psum(1, axis)
    if isinstance(n, jax.Array):  # abstract axis size — fall back
        return jax.lax.psum(x, axis)

    def body(i, acc_cur):
        acc, cur = acc_cur
        nxt = jax.lax.ppermute(
            cur, axis, [(j, (j + 1) % n) for j in range(n)])
        return acc + nxt, nxt

    acc, _ = jax.lax.fori_loop(0, n - 1, body, (x, x))
    return acc


# ---------------------------------------------------------------------------
# distributed seeding
# ---------------------------------------------------------------------------

def _dist_kmeanspp_local(key, pts_local, k, axes, variant):
    """Body run inside shard_map. pts_local: (n_local, d)."""
    n_local, d = pts_local.shape
    pts = pts_local.astype(jnp.float32)

    # first seed: uniform over the GLOBAL point set
    key, k0 = jax.random.split(key)
    first = dist_gumbel_choice(k0, jnp.zeros((n_local,), jnp.float32), axes)
    c0 = take_global(pts, first, axes)

    centroids = jnp.zeros((k, d), jnp.float32).at[0].set(c0)
    indices = jnp.zeros((k,), jnp.int32).at[0].set(first)
    min_d2 = _pvary(jnp.full((n_local,), jnp.inf, jnp.float32), axes)

    use_pallas = variant.startswith("pallas")

    def round_update(md, c_new):
        if use_pallas:
            from repro.kernels import ops as kops
            md, parts = kops.distance_min_update(
                pts, c_new[None, :], md,
                resident_centroids=(variant == "pallas_constant"))
            local_total = jnp.sum(parts)
        else:
            md = jnp.minimum(md, point_d2(pts, c_new))
            local_total = jnp.sum(md)
        return md, local_total

    def body(m, carry):
        key, centroids, indices, min_d2 = carry
        min_d2, _local_total = round_update(min_d2, centroids[m - 1])
        # the paper's thrust::reduce -> psum of local partial sums. The Gumbel
        # sampler doesn't need the normalizer, but production logging does (the
        # potential phi), so we keep the collective - it is O(1) bytes.
        _phi = jax.lax.psum(_local_total, axes)
        key, ks = jax.random.split(key)
        nxt = dist_gumbel_choice(ks, sampling.safe_log(min_d2), axes)
        c_new = take_global(pts, nxt, axes)
        centroids = jax.lax.dynamic_update_index_in_dim(centroids, c_new, m, 0)
        indices = indices.at[m].set(nxt)
        return key, centroids, indices, min_d2

    key, centroids, indices, min_d2 = jax.lax.fori_loop(
        1, k, body, (key, centroids, indices, min_d2))
    min_d2, _ = round_update(min_d2, centroids[k - 1])
    return centroids, indices, min_d2


def dist_kmeanspp(key: jax.Array, points: jax.Array, k: int, *, mesh: Mesh,
                  axes: str | Sequence[str] = "data",
                  variant: str = "fused") -> KmeansppResult:
    """Distributed k-means++ seeding. `points` sharded on axis 0 over `axes`."""
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    fn = functools.partial(_dist_kmeanspp_local, k=k, axes=axes_t,
                           variant=variant)
    mapped = jax.shard_map(
        lambda kk, pp: fn(kk, pp),
        mesh=mesh,
        in_specs=(P(), P(axes_t)),
        out_specs=(P(), P(), P(axes_t)),
    )
    centroids, indices, min_d2 = jax.jit(mapped)(key, points)
    return KmeansppResult(centroids.astype(points.dtype), indices, min_d2)


# ---------------------------------------------------------------------------
# distributed Lloyd
# ---------------------------------------------------------------------------

def _dist_lloyd_local(pts_local, init_centroids, axes, max_iters, tol):
    pts = pts_local.astype(jnp.float32)
    k = init_centroids.shape[0]

    def assign_local(cents):
        d2 = pairwise_d2(pts, cents)
        a = jnp.argmin(d2, axis=1).astype(jnp.int32)
        return a, jnp.min(d2, axis=1)

    def body(state):
        i, cents, _, inertia, _ = state
        a, m = assign_local(cents)
        local_inertia = jnp.sum(m)
        new_inertia = jax.lax.psum(local_inertia, axes)
        sums = jax.ops.segment_sum(pts, a, num_segments=k)
        counts = jax.ops.segment_sum(jnp.ones_like(m), a, num_segments=k)
        sums = jax.lax.psum(sums, axes)      # O(k*d) per iteration
        counts = jax.lax.psum(counts, axes)  # O(k)
        new_cents = jnp.where((counts > 0)[:, None],
                              sums / jnp.maximum(counts, 1e-12)[:, None], cents)
        return i + 1, new_cents, inertia, new_inertia, a

    def cond(state):
        i, _, prev, cur, _ = state
        rel = (prev - cur) / jnp.maximum(prev, 1e-30)
        return jnp.logical_and(i < max_iters, jnp.logical_or(i < 2, rel > tol))

    n_local = pts.shape[0]
    init = (jnp.zeros((), jnp.int32), init_centroids.astype(jnp.float32),
            jnp.inf, jnp.inf,
            _pvary(jnp.zeros((n_local,), jnp.int32), axes))
    i, cents, _, inertia, a = jax.lax.while_loop(cond, body, init)
    return cents, a, inertia, i


def dist_lloyd(points: jax.Array, init_centroids: jax.Array, *, mesh: Mesh,
               axes: str | Sequence[str] = "data", max_iters: int = 50,
               tol: float = 1e-6) -> LloydResult:
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    fn = functools.partial(_dist_lloyd_local, axes=axes_t,
                           max_iters=max_iters, tol=tol)
    mapped = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(axes_t), P()),
        out_specs=(P(), P(axes_t), P(), P()),
    )
    cents, a, inertia, i = jax.jit(mapped)(points, init_centroids)
    return LloydResult(cents.astype(points.dtype), a, inertia, i)


def dist_kmeans(key: jax.Array, points: jax.Array, k: int, *, mesh: Mesh,
                axes: str | Sequence[str] = "data", variant: str = "fused",
                max_iters: int = 50) -> LloydResult:
    seeds = dist_kmeanspp(key, points, k, mesh=mesh, axes=axes, variant=variant)
    return dist_lloyd(points, seeds.centroids, mesh=mesh, axes=axes,
                      max_iters=max_iters)
