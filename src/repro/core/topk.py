"""Blocked lexicographic top-k: THE merge the IVF scan carries across tiles.

A streaming top-k over tile-blocked candidates is only bitwise equal to a
global top-k when the per-merge order is a strict TOTAL order — plain
``lax.top_k`` on distances leaves ties ordered by visit order, which differs
between a brute-force pass and a tile-blocked scan. Every merge here sorts
by the lexicographic key ``(value, index)`` (``jax.lax.sort`` with
``num_keys=2``): indices are unique, so the order is total, every merge is
associative over candidate batches, and the scan's carried top-k equals the
global sort's first k rows bitwise no matter how the candidates were
blocked — the exactness anchor ``serve.ivf`` pins at ``nprobe == nlist``.

Sentinels: empty slots hold ``(+inf, INT32_MAX)``, which lexicographically
trails every real candidate (a finite d2 beats +inf; a real index beats the
sentinel on a +inf tie), so partially-filled merges need no masking. Shared
verbatim by the Pallas scan kernels, their pure-jnp twins, and the
brute-force oracle, so all three tie-break identically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

IDX_SENTINEL = jnp.iinfo(jnp.int32).max


def init_topk(k: int) -> tuple[jax.Array, jax.Array]:
    """Empty carried top-k: (+inf values, INT32_MAX indices)."""
    return (jnp.full((k,), jnp.inf, jnp.float32),
            jnp.full((k,), IDX_SENTINEL, jnp.int32))


def lex_topk(vals: jax.Array, idxs: jax.Array,
             k: int) -> tuple[jax.Array, jax.Array]:
    """Smallest k of (vals, idxs) under the lexicographic (value, index)
    order — ascending sort with num_keys=2, first k rows."""
    sv, si = jax.lax.sort((vals.astype(jnp.float32), idxs.astype(jnp.int32)),
                          num_keys=2)
    return sv[:k], si[:k]


def merge_topk(top_vals: jax.Array, top_idxs: jax.Array, cand_vals: jax.Array,
               cand_idxs: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """One blocked-merge step: carried top-k + a candidate block -> new
    top-k. Associative over blocks (total order), so any tiling of the
    candidate stream yields the global :func:`lex_topk` bitwise."""
    return lex_topk(jnp.concatenate([top_vals, cand_vals]),
                    jnp.concatenate([top_idxs, cand_idxs]), k)
