"""K-means++ seeding — serial (paper's CPU baseline) and parallel (paper's contribution).

The paper parallelizes the seeding phase of k-means++: after each new centroid is
chosen, the distance of every point to its nearest centroid is updated in parallel
(one CUDA thread per point), the normalization term ``sum(D^2)`` is computed with a
parallel reduction (Thrust), and the next centroid is sampled with probability
proportional to ``D^2``.

TPU adaptation (see DESIGN.md §2): the thread-per-point grid becomes a Pallas grid
over point tiles; the paper's *constant memory* (centroids) becomes a VMEM-resident
centroid block; *texture memory* (points) becomes the pipelined HBM->VMEM stream with
a fused single-pass min-update + partial-sum kernel.

Variants (``variant=``):
  ``serial``          — fori_loop over points *and* a separate reduction pass: the
                        paper's CPU baseline, one point at a time.
  ``global``          — vectorized distance update materialized to HBM, then a
                        *separate* reduction pass re-reading min_d2 (global-memory
                        semantics: two passes over the array).
  ``fused``           — single fused pass: min-update and partial sum in one program
                        (constant/texture-memory semantics; XLA fuses on CPU/TPU).
  ``pallas_constant`` — Pallas kernel, centroid block VMEM-resident across the grid.
  ``pallas_fused``    — Pallas kernel, fused min-update + per-tile partial sums
                        (points read exactly once — the texture-memory analogue).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sampling


class KmeansppResult(NamedTuple):
    centroids: jax.Array   # (k, d)
    indices: jax.Array     # (k,) int32 — which data points were chosen
    min_d2: jax.Array      # (n,) final D^2 to nearest seed (useful for k-means||)


def pairwise_d2(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared euclidean distances (n, d) x (k, d) -> (n, k); MXU-friendly form."""
    xn = jnp.sum(x * x, axis=-1, keepdims=True)
    cn = jnp.sum(c * c, axis=-1)
    d2 = xn - 2.0 * (x @ c.T) + cn[None, :]
    return jnp.maximum(d2, 0.0)


def point_d2(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared euclidean distance of every point in x (n, d) to one centroid (d,)."""
    diff = x - c[None, :]
    return jnp.sum(diff * diff, axis=-1)


# ---------------------------------------------------------------------------
# Round updates: (points, new_centroid, min_d2) -> (min_d2', total)
# ---------------------------------------------------------------------------

def _round_serial(points, c_new, min_d2, weights):
    """Paper CPU baseline: one point at a time, then a second serial pass to sum."""
    n = points.shape[0]

    def body(i, md):
        diff = points[i] - c_new
        d2 = jnp.sum(diff * diff)
        return md.at[i].set(jnp.minimum(md[i], d2))

    min_d2 = jax.lax.fori_loop(0, n, body, min_d2)

    def sum_body(i, acc):
        w = min_d2[i] if weights is None else min_d2[i] * weights[i]
        return acc + w

    total = jax.lax.fori_loop(0, n, sum_body, jnp.zeros((), min_d2.dtype))
    return min_d2, total


def _round_global(points, c_new, min_d2, weights):
    """Parallel update materialized, separate reduction pass (global-memory analogue)."""
    d2 = point_d2(points, c_new)
    min_d2 = jnp.minimum(min_d2, d2)
    # `optimization_barrier` forces the reduction to be a second pass over the
    # materialized array instead of fusing — mirrors the two-kernel CUDA structure.
    min_d2 = jax.lax.optimization_barrier(min_d2)
    w = min_d2 if weights is None else min_d2 * weights
    return min_d2, jnp.sum(w)


def _round_fused(points, c_new, min_d2, weights):
    """Fused single pass (constant/texture analogue): XLA fuses update + reduce."""
    d2 = point_d2(points, c_new)
    min_d2 = jnp.minimum(min_d2, d2)
    w = min_d2 if weights is None else min_d2 * weights
    return min_d2, jnp.sum(w)


def _round_pallas(points, c_new, min_d2, weights, *, resident: bool):
    from repro.kernels import ops as kops
    min_d2, partials = kops.distance_min_update(
        points, c_new[None, :], min_d2, resident_centroids=resident)
    total = jnp.sum(partials)
    if weights is not None:
        # weighted total needs the weighted sum; recompute cheaply (weights case is
        # only used by the small candidate reduce in k-means||).
        total = jnp.sum(min_d2 * weights)
    return min_d2, total


_ROUND_IMPLS = {
    "serial": _round_serial,
    "global": _round_global,
    "fused": _round_fused,
    "pallas_constant": functools.partial(_round_pallas, resident=True),
    "pallas_fused": functools.partial(_round_pallas, resident=False),
}


# ---------------------------------------------------------------------------
# Full seeding
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "variant", "sampler"))
def kmeanspp(
    key: jax.Array,
    points: jax.Array,
    k: int,
    *,
    weights: Optional[jax.Array] = None,
    variant: str = "fused",
    sampler: str = "cdf",
) -> KmeansppResult:
    """K-means++ seeding. Returns k centroids chosen from `points`.

    sampler: 'cdf' (inverse-CDF, matches the serial algorithm exactly so that
    serial and parallel variants pick identical seeds under the same key) or
    'gumbel' (Gumbel-max; the building block of the distributed version).
    """
    n, d = points.shape
    if not 0 < k <= n:
        raise ValueError(f"need 0 < k <= n, got k={k}, n={n}")
    round_fn = _ROUND_IMPLS[variant]
    compute_dtype = jnp.promote_types(points.dtype, jnp.float32)
    pts = points.astype(compute_dtype)
    w = None if weights is None else weights.astype(compute_dtype)

    key, k0 = jax.random.split(key)
    if w is None:
        first = jax.random.randint(k0, (), 0, n, dtype=jnp.int32)
    else:  # first seed weighted by point weights (k-means|| reduce step)
        first = sampling.categorical(k0, w, method="cdf").astype(jnp.int32)

    centroids = jnp.zeros((k, d), compute_dtype).at[0].set(pts[first])
    indices = jnp.zeros((k,), jnp.int32).at[0].set(first)
    min_d2 = jnp.full((n,), jnp.inf, compute_dtype)

    def body(m, carry):
        key, centroids, indices, min_d2 = carry
        c_prev = centroids[m - 1]
        min_d2, total = round_fn(pts, c_prev, min_d2, w)
        del total  # the paper's thrust::reduce term — kept for phi logging;
        # the cdf sampler normalizes by its OWN cumsum's last entry instead:
        # serial and parallel reductions sum in different orders, and a 1-ulp
        # difference in the scale flips boundary samples. With cdf[-1] every
        # variant picks bitwise-identical seeds (the paper's quality claim,
        # verified exactly in tests/test_kmeanspp.py).
        key, ks = jax.random.split(key)
        weight = min_d2 if w is None else min_d2 * w
        nxt = sampling.categorical(ks, weight, method=sampler)
        nxt = nxt.astype(jnp.int32)
        centroids = jax.lax.dynamic_update_index_in_dim(centroids, pts[nxt], m, 0)
        indices = indices.at[m].set(nxt)
        return key, centroids, indices, min_d2

    key, centroids, indices, min_d2 = jax.lax.fori_loop(
        1, k, body, (key, centroids, indices, min_d2))
    # final D^2 update against the last chosen centroid (callers like k-means||
    # want the potential phi = sum min_d2 over *all* k centroids).
    min_d2, _ = round_fn(pts, centroids[k - 1], min_d2, w)
    return KmeansppResult(centroids.astype(points.dtype), indices, min_d2)


def random_init(key: jax.Array, points: jax.Array, k: int) -> KmeansppResult:
    """Classic k-means random seeding (the baseline k-means++ improves upon)."""
    n = points.shape[0]
    idx = jax.random.choice(key, n, (k,), replace=False).astype(jnp.int32)
    cents = points[idx]
    min_d2 = jnp.min(pairwise_d2(points.astype(jnp.float32),
                                 cents.astype(jnp.float32)), axis=1)
    return KmeansppResult(cents, idx, min_d2)
