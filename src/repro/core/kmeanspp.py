"""K-means++ seeding — serial (paper's CPU baseline) and parallel (paper's contribution).

The paper parallelizes the seeding phase of k-means++: after each new centroid is
chosen, the distance of every point to its nearest centroid is updated in parallel
(one CUDA thread per point), the normalization term ``sum(D^2)`` is computed with a
parallel reduction (Thrust), and the next centroid is sampled with probability
proportional to ``D^2``.

TPU adaptation (see DESIGN.md §2): the thread-per-point grid becomes a Pallas grid
over point tiles; the paper's *constant memory* (centroids) becomes a VMEM-resident
centroid block; *texture memory* (points) becomes the pipelined HBM->VMEM stream with
a fused single-pass min-update + partial-sum kernel.

This module is now a thin compatibility shim over ``repro.core.engine``: the
round update lives in the engine's Backend protocol and the historical
``variant=`` strings map onto backends:

  ``serial``          -> ReferenceBackend(mode='serial')   (paper CPU baseline)
  ``global``          -> ReferenceBackend(mode='global')   (two-pass update)
  ``fused``           -> FusedBackend                      (XLA single pass)
  ``pallas_constant`` -> PallasBackend(resident=True)      (VMEM-resident centroids)
  ``pallas_fused``    -> PallasBackend(resident=False)     (streamed, fused pass)

All variants pick bitwise-identical seeds under the same key; the engine's
seed-parity tests pin this.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine import (KmeansppResult, make_backend, pairwise_d2,
                               point_d2)

__all__ = ["KmeansppResult", "kmeanspp", "random_init", "pairwise_d2",
           "point_d2"]


@functools.partial(jax.jit, static_argnames=("k", "variant", "sampler"))
def kmeanspp(
    key: jax.Array,
    points: jax.Array,
    k: int,
    *,
    weights: Optional[jax.Array] = None,
    variant: str = "fused",
    sampler: str = "cdf",
) -> KmeansppResult:
    """K-means++ seeding. Returns k centroids chosen from `points`.

    sampler: 'cdf' (inverse-CDF, matches the serial algorithm exactly so that
    serial and parallel variants pick identical seeds under the same key) or
    'gumbel' (Gumbel-max; the building block of the distributed version).
    """
    n, d = points.shape
    if not 0 < k <= n:
        raise ValueError(f"need 0 < k <= n, got k={k}, n={n}")
    return engine.seed_points(key, points, k, weights, make_backend(variant),
                              sampler)


def random_init(key: jax.Array, points: jax.Array, k: int) -> KmeansppResult:
    """Classic k-means random seeding (the baseline k-means++ improves upon)."""
    n = points.shape[0]
    idx = jax.random.choice(key, n, (k,), replace=False).astype(jnp.int32)
    cents = points[idx]
    min_d2 = jnp.min(pairwise_d2(points.astype(jnp.float32),
                                 cents.astype(jnp.float32)), axis=1)
    return KmeansppResult(cents, idx, min_d2)
