"""Input guards + the typed failure vocabulary of the clustering stack.

PRs 3-6 made every round primitive fast by *carrying state across rounds*
(Hamerly bounds, stale tile partials, rejection envelopes), which means one
NaN row, one negative weight, or one poisoned carry now corrupts *every
subsequent round* instead of one. This module is the single place the
engine's failure semantics are named:

* a ``validate="raise" | "sanitize" | "off"`` policy applied at every
  ``ClusterEngine`` entry point (``seed`` / ``fit`` / ``kmeans`` /
  ``*_batched`` / ``fit_minibatch``) — NaN/Inf rows, degenerate or negative
  weights, and k/n/d shape abuse are caught BEFORE they enter a jitted
  loop, where they could only propagate silently;
* the :class:`ClusteringError` hierarchy — every fault the stack can
  surface is a typed subclass, so callers (and the fault-injection matrix
  in ``tests/test_faults.py``) can assert "recovered bitwise OR raised
  typed, never a silent wrong answer".

Entry validation is a host-side pass over concrete arrays (the entry
points are untraced); the *in-flight* corruption detection lives inside
the jitted loops instead (see ``engine._seed_loop`` / ``engine._fit_loop``
and the ``recovered`` counters in ``core.telemetry``), because a NaN that
appears mid-loop cannot raise from inside ``lax.while_loop``.

The sanitize path is allocation-free when the input is clean: the original
array is returned unchanged (bitwise), so ``validate="sanitize"`` costs one
streaming ``isfinite`` reduction per entry and nothing per round.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = [
    "ClusteringError", "InvalidInputError", "CorruptedStateError",
    "PipelineError", "KernelFailureError", "CheckpointError",
    "POLICIES", "check_policy", "check_shape", "guard_points",
    "guard_weights", "guard_centroids",
]


# ---------------------------------------------------------------------------
# the typed failure vocabulary
# ---------------------------------------------------------------------------


class ClusteringError(Exception):
    """Base of every typed failure the clustering stack raises. The fault
    matrix's contract: every injected fault either recovers to a
    bitwise-correct result or raises a ClusteringError subclass."""


class InvalidInputError(ClusteringError, ValueError):
    """Malformed caller input: NaN/Inf rows under validate='raise',
    negative/degenerate weights, k/n/d shape abuse. Subclasses ValueError so
    historical ``raises(ValueError)`` call sites keep working."""


class CorruptedStateError(ClusteringError, RuntimeError):
    """Loop-carried state (bound state, envelope, checkpoint carry) found
    poisoned where in-loop recovery is not available."""


class PipelineError(ClusteringError, RuntimeError):
    """The data pipeline's read path failed past its retry budget. Carries
    the failing step index."""

    def __init__(self, message: str, *, step: Optional[int] = None):
        super().__init__(message)
        self.step = step


class KernelFailureError(ClusteringError, RuntimeError):
    """A Pallas kernel failed to compile/launch. The engine's backend
    fallback chain (pallas -> fused -> reference) catches this; it escapes
    only when the whole chain is exhausted."""


class CheckpointError(ClusteringError, RuntimeError):
    """Checkpoint save/restore failed or the manifest is incompatible with
    the requested restore (wrong problem shape, unsupported carry)."""


# ---------------------------------------------------------------------------
# entry-point validation
# ---------------------------------------------------------------------------

POLICIES = ("raise", "sanitize", "off")


def check_policy(validate: str) -> str:
    if validate not in POLICIES:
        raise InvalidInputError(
            f"unknown validate policy {validate!r}; expected one of "
            f"{POLICIES}")
    return validate


def check_shape(k: int, n: int, *, d: Optional[int] = None,
                what: str = "seed") -> None:
    """k/n/d shape abuse is never sanitizable — always typed raise."""
    if not 0 < k <= n:
        raise InvalidInputError(f"need 0 < k <= n, got k={k}, n={n}")
    if d is not None and d < 1:
        raise InvalidInputError(f"{what}: need d >= 1, got d={d}")


def _count_bad(mask) -> int:
    # one device reduction + one scalar sync; the whole cost of a guard
    # pass on clean input
    return int(jnp.sum(mask))


def guard_points(points, policy: str, *, name: str = "points"):
    """NaN/Inf entries: 'raise' -> InvalidInputError, 'sanitize' -> the
    offending ROWS are zeroed (a zero row is a valid, finite point — it
    clusters like any other instead of poisoning every D^2 it touches),
    'off' -> passthrough. Clean input is returned unchanged (bitwise)."""
    if policy == "off":
        return points
    x = jnp.asarray(points)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return points
    finite = jnp.isfinite(x)
    n_bad = _count_bad(~finite)
    if n_bad == 0:
        return points
    if policy == "raise":
        raise InvalidInputError(
            f"{name} has {n_bad} non-finite entries; pass "
            f"validate='sanitize' to zero the offending rows or "
            f"validate='off' to skip the check")
    row_ok = jnp.all(finite, axis=-1, keepdims=True)
    return jnp.where(row_ok, x, jnp.zeros((), x.dtype))


def guard_weights(weights, n: int, policy: str):
    """Degenerate weights: NaN/Inf/negative entries raise or clamp to 0;
    an all-zero (or sanitized-to-zero) weight vector always raises — there
    is no distribution to sample from. Shape mismatch always raises."""
    if weights is None:
        return None
    w = jnp.asarray(weights)
    if w.shape != (n,):
        raise InvalidInputError(
            f"weights shape {w.shape} != ({n},)")
    if policy == "off":
        return weights
    bad = ~jnp.isfinite(w) | (w < 0)
    n_bad = _count_bad(bad)
    if n_bad:
        if policy == "raise":
            raise InvalidInputError(
                f"weights has {n_bad} negative/non-finite entries")
        w = jnp.where(bad, jnp.zeros((), w.dtype), w)
    if not bool(jnp.any(w > 0)):
        raise InvalidInputError("weights sum to zero: nothing to sample")
    return w


def guard_centroids(centroids, d: int, policy: str, *,
                    name: str = "init_centroids"):
    """Initial centroids: NaN/Inf always raises (a sanitized-to-zero
    centroid silently moves the optimum — worse than failing); shape abuse
    always raises."""
    c = jnp.asarray(centroids)
    if c.shape[-1] != d:
        raise InvalidInputError(
            f"{name} dimension {c.shape[-1]} != points dimension {d}")
    if policy == "off":
        return centroids
    n_bad = _count_bad(~jnp.isfinite(c))
    if n_bad:
        raise InvalidInputError(
            f"{name} has {n_bad} non-finite entries")
    return centroids
